//! Varint + fixed-width primitives for the wire protocol.

use anyhow::{bail, Result};

/// LEB128 unsigned varint (token ids fit in 2 bytes for vocab <= 16k).
pub fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

pub fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&b) = buf.get(*pos) else {
            bail!("varint: truncated");
        };
        *pos += 1;
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            bail!("varint: overlong");
        }
    }
}

pub fn write_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn read_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > buf.len() {
        bail!("u32: truncated");
    }
    let v = u32::from_le_bytes(buf[*pos..*pos + 4].try_into().unwrap());
    *pos += 4;
    Ok(v)
}

pub fn write_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn read_u16(buf: &[u8], pos: &mut usize) -> Result<u16> {
    if *pos + 2 > buf.len() {
        bail!("u16: truncated");
    }
    let v = u16::from_le_bytes(buf[*pos..*pos + 2].try_into().unwrap());
    *pos += 2;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn varint_known_values() {
        let mut out = Vec::new();
        write_varint(&mut out, 0);
        write_varint(&mut out, 127);
        write_varint(&mut out, 128);
        write_varint(&mut out, 300);
        assert_eq!(out, vec![0, 0x7f, 0x80, 0x01, 0xac, 0x02]);
        let mut pos = 0;
        assert_eq!(read_varint(&out, &mut pos).unwrap(), 0);
        assert_eq!(read_varint(&out, &mut pos).unwrap(), 127);
        assert_eq!(read_varint(&out, &mut pos).unwrap(), 128);
        assert_eq!(read_varint(&out, &mut pos).unwrap(), 300);
        assert_eq!(pos, out.len());
    }

    #[test]
    fn varint_roundtrip_property() {
        prop::check(500, |rng| {
            let v = rng.next_u64() >> (rng.next_range(60) as u32);
            let mut out = Vec::new();
            write_varint(&mut out, v);
            let mut pos = 0;
            let back = read_varint(&out, &mut pos).map_err(|e| e.to_string())?;
            prop::assert_prop(back == v && pos == out.len(), format!("{v} != {back}"))
        });
    }

    #[test]
    fn varint_rejects_truncation_and_overlong() {
        let mut pos = 0;
        assert!(read_varint(&[0x80], &mut pos).is_err());
        let overlong = vec![0x80u8; 10];
        let mut pos = 0;
        assert!(read_varint(&overlong, &mut pos).is_err());
    }

    #[test]
    fn fixed_width_roundtrip() {
        let mut out = Vec::new();
        write_u32(&mut out, 0xdead_beef);
        write_u16(&mut out, 0xcafe);
        let mut pos = 0;
        assert_eq!(read_u32(&out, &mut pos).unwrap(), 0xdead_beef);
        assert_eq!(read_u16(&out, &mut pos).unwrap(), 0xcafe);
        let mut bad = 3;
        assert!(read_u32(&out, &mut bad).is_err() || out.len() >= 7);
    }
}
