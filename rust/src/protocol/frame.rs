//! Length-prefixed, stream-multiplexed frame codec + wire-format version
//! handshake for the `serve` subsystem (real sockets, not the
//! byte-accounting simulation).
//!
//! Every frame on the stream is `[len: u32 le][kind: u8][stream: u32 le]
//! [payload]` where `len = 1 + 4 + payload.len()`. The codec is
//! incremental (`FrameDecoder` accepts arbitrary byte splits — TCP
//! guarantees neither message boundaries nor single-read delivery) and
//! bounded (`MAX_FRAME_BYTES` rejects hostile or corrupt length prefixes
//! before allocation).
//!
//! # Multiplexed connection lifecycle (wire v2)
//!
//! One connection carries ONE handshake and MANY sessions. Stream id 0
//! ([`CONTROL_STREAM`]) is reserved for connection-scoped control frames
//! (`Hello`/`HelloAck`); every session lives on its own nonzero stream:
//!
//! ```text
//! edge                                cloud
//!  s0 Hello{wire_version} ─────────▶       version gate (reject ≠ WIRE_VERSION)
//!          ◀───────── s0 HelloAck{accepted}
//!  s1 Open{prompt, max_new, nonce} ▶       KV session created
//!          ◀───────── s1 OpenAck{session, target_seq, resume_token}
//!  s2 Open{...} ───────────────────▶       second session, same connection
//!  s1 Draft{DraftMsg} ─────────────▶       cross-connection verification batcher
//!  s2 Draft{DraftMsg} ─────────────▶
//!          ◀───────── s2 Verify{VerifyMsg}     (replies interleave freely)
//!          ◀───────── s1 Verify{VerifyMsg}
//!  ...                                      (target hot-swaps never drop this)
//!  s1 Bye ─────────────────────────▶       session closed; s2 keeps decoding
//! ```
//!
//! # Reconnect-and-resume handshake
//!
//! When the transport dies, the cloud PARKS every session the connection
//! carried (KV state kept alive for a grace window) instead of aborting
//! it. The edge dials a fresh connection and replays, per session, a
//! resume handshake carrying the session token from `OpenAck` and its
//! last committed position:
//!
//! ```text
//! edge (new connection)               cloud
//!  s0 Hello ───────────────────────▶
//!          ◀───────── s0 HelloAck
//!  s7 Resume{token, committed_len} ─▶      un-park; compute missing tail
//!          ◀───────── s7 ResumeAck{tail, rounds, done, ...}
//!  s7 Draft{...} ───────────────────▶      decoding continues from the
//!                                          committed prefix — no re-sync
//! ```
//!
//! The server is the source of truth: its committed sequence can only be
//! AHEAD of the edge's (a verdict applied whose reply was lost), never
//! behind, so `ResumeAck.tail` is exactly the suffix the edge is missing.
//! A session that finished while the link was down resumes with
//! `done = true` and the final tail. `Open` carries a client nonce so a
//! retransmitted open (ack lost mid-handshake) reattaches the existing
//! session instead of leaking a second one.

use super::codec::{read_u16, read_u32, read_varint, write_u16, write_u32, write_varint};
use super::VerifyMode;
use anyhow::{bail, Result};

/// Version of the frame layout + message payloads. Bump on any breaking
/// change; the handshake NEGOTIATES the highest mutually supported
/// version instead of misinterpreting bytes. v2: stream-multiplexed
/// framing + the resume handshake (`Resume`/`ResumeAck`, open nonces,
/// resume tokens). v3: pipelined drafting — speculative-basis-tagged
/// `Draft` payloads (`DraftMsg::{basis_len, spec}`) and the `Cancel`
/// frame that retracts in-flight speculative rounds after a partial
/// acceptance. v4: admission control — the cloud may answer a draft
/// with a `Busy` frame instead of a verdict when its pending-draft
/// queue is saturated; the edge retries the identical draft after
/// `retry_after_ms` (with backoff), so committed tokens never change.
/// v5: fleet serving — a draining or saturated replica may answer a
/// draft with a `Redirect { addr, resume_token }` frame that hands the
/// session to a peer replica (the edge redials and replays the normal
/// `Resume` there), and the cloud announces `ReplicaInfo { version,
/// load }` telemetry on the control stream after the handshake.
/// v6: wire-level stats — an edge (or a fleet registry probing a
/// replica out-of-band) may send a `Stats` request on the control
/// stream and receives a `StatsAck` snapshot carrying the replica's
/// serving counters plus its mergeable latency histograms
/// (`obs::LatencySummary`). Read-only and connection-scoped: a lost or
/// reordered `Stats` exchange can never affect a committed token.
/// v7: QoS tiers — `Open` grows an OPTIONAL trailing tier varint
/// (encoded only when != 1, so a default-tier v7 open is byte-identical
/// to v6); the cloud reserves `tier_reserve` admission slots for
/// tier > 1 sessions, mirroring the edge mux's weighted tiers. Tiers
/// only shape Busy backpressure — committed tokens never change.
/// v8: heterogeneous devices + tree speculation — `Open` grows an
/// OPTIONAL trailing [`DeviceProfileMsg`] (compute tier, channel
/// class, energy budget) behind the tier varint; `Draft` grows an
/// optional tree-topology tail (`DraftMsg::tree`, parent pointers
/// behind a zero-length spec marker every pre-v8 decoder rejects) so
/// the edge can ship a token TREE whose root→leaf paths the cloud
/// verifies as ragged rows of one stacked batch; `Verify` grows an
/// optional trailing leaf byte (`VerifyMsg::leaf`) naming the winning
/// path. All three tails are absent for default-profile linear
/// traffic, which stays byte-identical to v7.
pub const WIRE_VERSION: u16 = 8;

/// Oldest peer version the handshake still accepts. A v2 peer never
/// sends spec-tagged drafts or `Cancel` frames, and the cloud sends it
/// nothing new, so v5 clouds serve v2..v4 edges unchanged; the
/// negotiated version in `HelloAck` tells the edge whether pipelining
/// (>= 3) is allowed on the connection, tells the cloud whether the
/// peer understands `Busy` (>= 4) — drafts from older peers are always
/// admitted because they could not act on a deferral — whether the
/// peer can follow a `Redirect` to a fleet sibling (>= 5; older peers
/// are never redirected and simply keep decoding on this replica), and
/// whether `Stats`/`StatsAck` snapshots may flow on the control stream
/// (>= 6; older peers never see either frame).
pub const MIN_WIRE_VERSION: u16 = 2;

/// Upper bound on one frame's body (kind + stream + payload). Prompts are
/// ≤ a few hundred tokens and draft blocks ≤ K_max tokens, so 1 MiB is
/// generous.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Stream id reserved for connection-scoped control frames
/// (`Hello`/`HelloAck`). Session frames must use a nonzero stream.
pub const CONTROL_STREAM: u32 = 0;

/// Frame body bytes before the payload: kind (1) + stream (4). Public
/// so byte-accounting consumers (e.g. the fault injector's delay
/// sampling) stay in lockstep with the layout.
pub const FRAME_HEAD: usize = 5;

/// Frame discriminator (first payload byte after the length prefix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Edge → cloud: wire-format version + verify mode announcement.
    Hello = 1,
    /// Cloud → edge: handshake verdict.
    HelloAck = 2,
    /// Edge → cloud: open a session (prompt + output budget + nonce).
    Open = 3,
    /// Cloud → edge: session id + resume token + target version sequence.
    OpenAck = 4,
    /// Edge → cloud: one `DraftMsg` draft block.
    Draft = 5,
    /// Cloud → edge: one `VerifyMsg` verification verdict.
    Verify = 6,
    /// Edge → cloud: orderly end of one session (the connection and its
    /// other streams live on).
    Bye = 7,
    /// Edge → cloud: reattach a parked session after a transport drop.
    Resume = 8,
    /// Cloud → edge: resume verdict + the committed tail the edge missed.
    ResumeAck = 9,
    /// Edge → cloud (wire v3): retract in-flight speculative draft
    /// rounds `>= round` after a partial acceptance broke their
    /// optimistic prefix. Advisory fast-path: the cloud also discards
    /// stale drafts autonomously by basis check, so a lost `Cancel` can
    /// never change the committed sequence.
    Cancel = 10,
    /// Cloud → edge (wire v4): the pending-draft queue is saturated and
    /// this round was NOT admitted — retry the identical draft after
    /// `retry_after_ms`. Pure backpressure: the draft left no state
    /// behind, and a pure draft source re-produces byte-identical
    /// tokens from the same committed prefix, so deferral can never
    /// change a committed token (it only moves wall time).
    Busy = 11,
    /// Cloud → edge (wire v5): this replica is draining or saturated —
    /// the session has been exported to the fleet's shared handoff
    /// ledger and the edge should redial `addr` and replay the normal
    /// `Resume { resume_token, committed_len }` handshake there. Sent
    /// INSTEAD of a verdict for the session's next head round; the
    /// draft left no state behind, so the redirected session commits
    /// byte-identical tokens (drafts are pure functions of the
    /// committed prefix — the handoff only moves wall time). A peer
    /// that cannot follow the redirect resumes in place and the
    /// exporting replica re-imports the session from the ledger.
    Redirect = 12,
    /// Cloud → edge (wire v5, control stream): replica telemetry —
    /// deployed target version sequence + current load — announced once
    /// after the handshake. Informational: edges may log it, fleet
    /// registries read the same numbers out-of-band for placement.
    ReplicaInfo = 13,
    /// Edge → cloud (wire v6, control stream): request a metrics
    /// snapshot. Carries a client nonce echoed in the `StatsAck` so a
    /// poller can match replies to requests on a shared connection.
    Stats = 14,
    /// Cloud → edge (wire v6, control stream): metrics snapshot reply —
    /// serving counters + the four mergeable latency histograms.
    StatsAck = 15,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        Some(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::HelloAck,
            3 => FrameKind::Open,
            4 => FrameKind::OpenAck,
            5 => FrameKind::Draft,
            6 => FrameKind::Verify,
            7 => FrameKind::Bye,
            8 => FrameKind::Resume,
            9 => FrameKind::ResumeAck,
            10 => FrameKind::Cancel,
            11 => FrameKind::Busy,
            12 => FrameKind::Redirect,
            13 => FrameKind::ReplicaInfo,
            14 => FrameKind::Stats,
            15 => FrameKind::StatsAck,
            _ => return None,
        })
    }

    /// Connection-scoped control frames ride [`CONTROL_STREAM`]; every
    /// other kind is session-scoped and must name a nonzero stream.
    pub fn is_control(self) -> bool {
        matches!(
            self,
            FrameKind::Hello
                | FrameKind::HelloAck
                | FrameKind::ReplicaInfo
                | FrameKind::Stats
                | FrameKind::StatsAck
        )
    }

    /// Kinds that may bind a FRESH stream id. Everything else
    /// session-scoped must arrive on an already-bound stream.
    pub fn opens_stream(self) -> bool {
        matches!(self, FrameKind::Open | FrameKind::Resume)
    }
}

/// Demux guard shared by the cloud connection handler and the edge-side
/// multiplexer: control frames must use stream 0, session frames must
/// name a nonzero stream, and non-stream-opening session frames must
/// name a stream `is_bound` recognizes. (Duplicate `Open`/`Resume` on an
/// already-bound stream is NOT rejected here — the demux layer replays
/// the cached ack, absorbing transport-level retransmits.)
pub fn check_stream(
    kind: FrameKind,
    stream: u32,
    is_bound: impl Fn(u32) -> bool,
) -> Result<()> {
    if kind.is_control() {
        if stream != CONTROL_STREAM {
            bail!("{kind:?} frame must use control stream 0, got stream {stream}");
        }
        return Ok(());
    }
    if stream == CONTROL_STREAM {
        bail!("session frame {kind:?} on reserved control stream 0");
    }
    if !kind.opens_stream() && !is_bound(stream) {
        bail!("{kind:?} frame for unknown stream {stream}");
    }
    Ok(())
}

/// One wire frame: a kind tag + the stream it belongs to + an opaque
/// payload (message bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    /// 0 for connection control, the session's stream id otherwise.
    pub stream: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// A connection-scoped control frame (stream 0).
    pub fn control(kind: FrameKind, payload: Vec<u8>) -> Frame {
        Frame {
            kind,
            stream: CONTROL_STREAM,
            payload,
        }
    }

    /// A session frame on the given (nonzero) stream.
    pub fn on(stream: u32, kind: FrameKind, payload: Vec<u8>) -> Frame {
        Frame {
            kind,
            stream,
            payload,
        }
    }

    /// `[len: u32 le][kind: u8][stream: u32 le][payload]`,
    /// len = 5 + payload.len().
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + FRAME_HEAD + self.payload.len());
        out.extend_from_slice(&self.encode_head());
        out.extend_from_slice(&self.payload);
        out
    }

    /// Just the 9-byte prefix (`[len][kind][stream]`) of
    /// [`encode`](Self::encode): senders with vectored I/O write
    /// `[head, payload]` as two slices and skip copying the payload
    /// into a fresh buffer (`Transport::send_frame` hot path).
    pub fn encode_head(&self) -> [u8; 4 + FRAME_HEAD] {
        let mut head = [0u8; 4 + FRAME_HEAD];
        head[..4].copy_from_slice(&((FRAME_HEAD + self.payload.len()) as u32).to_le_bytes());
        head[4] = self.kind as u8;
        head[5..9].copy_from_slice(&self.stream.to_le_bytes());
        head
    }

    /// Total wire bytes [`encode`](Self::encode) would produce, without
    /// producing them (airtime metering on the vectored send path).
    pub fn encoded_len(&self) -> usize {
        4 + FRAME_HEAD + self.payload.len()
    }
}

/// Incremental frame parser over an arbitrary byte stream.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted lazily to amortize copies).
    off: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Feed raw bytes from the stream.
    pub fn push(&mut self, bytes: &[u8]) {
        // compact before growing if the dead prefix dominates
        if self.off > 4096 && self.off * 2 > self.buf.len() {
            self.buf.drain(..self.off);
            self.off = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as a frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let avail = &self.buf[self.off..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let mut pos = 0usize;
        let len = read_u32(avail, &mut pos)? as usize;
        if len < FRAME_HEAD || len > MAX_FRAME_BYTES {
            bail!("frame length {len} out of bounds ({FRAME_HEAD}..={MAX_FRAME_BYTES})");
        }
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let kind = FrameKind::from_u8(avail[4])
            .ok_or_else(|| anyhow::anyhow!("unknown frame kind {}", avail[4]))?;
        let mut spos = 5usize;
        let stream = read_u32(avail, &mut spos)?;
        let payload = avail[4 + FRAME_HEAD..4 + len].to_vec();
        self.off += 4 + len;
        if self.off == self.buf.len() {
            self.buf.clear();
            self.off = 0;
        }
        Ok(Some(Frame {
            kind,
            stream,
            payload,
        }))
    }
}

// ---------------------------------------------------------------------
// Handshake + session-control message payloads
// ---------------------------------------------------------------------

/// Edge → cloud greeting: the first frame on every connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub wire_version: u16,
    pub mode: VerifyMode,
    /// Largest draft block this edge will ever send (informational).
    pub k_max: u8,
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4);
        write_u16(&mut out, self.wire_version);
        out.push(match self.mode {
            VerifyMode::Greedy => 0,
            VerifyMode::Stochastic => 1,
        });
        out.push(self.k_max);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Hello> {
        let mut pos = 0usize;
        let wire_version = read_u16(buf, &mut pos)?;
        let mode = match buf.get(pos) {
            Some(0) => VerifyMode::Greedy,
            Some(1) => VerifyMode::Stochastic,
            _ => bail!("hello: bad mode byte"),
        };
        pos += 1;
        let k_max = *buf.get(pos).ok_or_else(|| anyhow::anyhow!("hello: truncated"))?;
        pos += 1;
        if pos != buf.len() {
            bail!("hello: trailing bytes");
        }
        Ok(Hello {
            wire_version,
            mode,
            k_max,
        })
    }
}

/// Cloud → edge handshake verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HelloAck {
    pub wire_version: u16,
    pub accepted: bool,
    /// Human-readable rejection reason (empty when accepted).
    pub reason: String,
}

impl HelloAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.reason.len());
        write_u16(&mut out, self.wire_version);
        out.push(self.accepted as u8);
        write_varint(&mut out, self.reason.len() as u64);
        out.extend_from_slice(self.reason.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<HelloAck> {
        let mut pos = 0usize;
        let wire_version = read_u16(buf, &mut pos)?;
        let accepted = match buf.get(pos) {
            Some(0) => false,
            Some(1) => true,
            _ => bail!("hello-ack: bad accepted byte"),
        };
        pos += 1;
        let n = read_varint(buf, &mut pos)? as usize;
        if pos + n != buf.len() {
            bail!("hello-ack: reason length mismatch");
        }
        let reason = String::from_utf8(buf[pos..pos + n].to_vec())?;
        Ok(HelloAck {
            wire_version,
            accepted,
            reason,
        })
    }
}

/// The cloud's answer to a `Hello`: the single place the version gate
/// lives, so the simulator-side tests and the server agree on it.
///
/// Since wire v3 the gate NEGOTIATES: any peer version in
/// [`MIN_WIRE_VERSION`, `WIRE_VERSION`] is accepted and the ack's
/// `wire_version` carries the agreed (lower) version — a v2 edge keeps
/// working against a v3 cloud, and a v3 edge talking to this cloud
/// learns from the ack whether v3-only traffic (spec-tagged drafts,
/// `Cancel`) is allowed on the connection.
pub fn hello_response(h: &Hello) -> HelloAck {
    if (MIN_WIRE_VERSION..=WIRE_VERSION).contains(&h.wire_version) {
        HelloAck {
            wire_version: h.wire_version.min(WIRE_VERSION),
            accepted: true,
            reason: String::new(),
        }
    } else {
        HelloAck {
            wire_version: WIRE_VERSION,
            accepted: false,
            reason: format!(
                "wire version mismatch: peer speaks v{}, this cloud speaks v{}..v{}",
                h.wire_version, MIN_WIRE_VERSION, WIRE_VERSION
            ),
        }
    }
}

/// Edge → cloud: open one serving session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpenMsg {
    pub prompt: Vec<i32>,
    pub max_new: u32,
    /// Client-chosen open nonce. A retransmitted `Open` (ack lost in a
    /// transport drop mid-handshake) carries the same nonce, and the
    /// cloud reattaches the already-created session instead of leaking a
    /// second KV session.
    pub nonce: u64,
    /// QoS tier (wire v7): 1 = default/bulk; higher tiers bypass the
    /// cloud's `tier_reserve` admission headroom. Encoded as an
    /// OPTIONAL trailing varint, present only when != 1 — a
    /// default-tier open is byte-identical to its v6 encoding, and a
    /// pre-v7 decoder (which rejects trailing bytes) never sees a tier
    /// because edges only send one after negotiating >= 7.
    pub tier: u32,
    /// Device profile (wire v8): who this session's edge IS — compute
    /// tier, channel class, remaining energy budget — so the cloud can
    /// observe (and a future placement layer exploit) the fleet's
    /// heterogeneity. Encoded as an OPTIONAL tail BEHIND the tier
    /// varint; when present the tier varint is always written (even the
    /// default 1) so the layout stays unambiguous. Absent profile +
    /// default tier is byte-identical to the v6/v7 encoding, and edges
    /// only send a profile after negotiating >= 8.
    pub profile: Option<DeviceProfileMsg>,
}

/// Wire form of a [`crate::device::DeviceProfile`] (wire v8): the three
/// numbers the cloud can act on without ever seeing the device model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceProfileMsg {
    /// Compute tier code: 0 = weak, 1 = mid, 2 = strong
    /// ([`crate::device::ComputeTier`]).
    pub compute_tier: u8,
    /// Channel class index into [`crate::channel::NetworkKind::all`].
    pub channel_class: u8,
    /// Remaining energy budget in millijoules (0 = unmetered).
    pub energy_mj: u64,
}

impl DeviceProfileMsg {
    fn encode_into(&self, out: &mut Vec<u8>) {
        out.push(self.compute_tier);
        out.push(self.channel_class);
        write_varint(out, self.energy_mj);
    }

    fn decode_from(buf: &[u8], pos: &mut usize) -> Result<DeviceProfileMsg> {
        let compute_tier = *buf.get(*pos).ok_or_else(|| anyhow::anyhow!("profile: truncated"))?;
        *pos += 1;
        let channel_class = *buf.get(*pos).ok_or_else(|| anyhow::anyhow!("profile: truncated"))?;
        *pos += 1;
        if compute_tier > 2 || channel_class > 2 {
            bail!("profile: bad tier/class ({compute_tier}/{channel_class})");
        }
        let energy_mj = read_varint(buf, pos)?;
        Ok(DeviceProfileMsg {
            compute_tier,
            channel_class,
            energy_mj,
        })
    }
}

impl OpenMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.prompt.len() * 2);
        write_u32(&mut out, self.max_new);
        write_varint(&mut out, self.nonce);
        write_varint(&mut out, self.prompt.len() as u64);
        for &t in &self.prompt {
            write_varint(&mut out, t as u64);
        }
        // the tier varint anchors the v8 profile tail, so a profiled
        // open writes it even at the default tier
        if self.tier != 1 || self.profile.is_some() {
            write_varint(&mut out, self.tier as u64);
        }
        if let Some(p) = &self.profile {
            p.encode_into(&mut out);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<OpenMsg> {
        let mut pos = 0usize;
        let max_new = read_u32(buf, &mut pos)?;
        let nonce = read_varint(buf, &mut pos)?;
        let n = read_varint(buf, &mut pos)? as usize;
        if n > MAX_FRAME_BYTES {
            bail!("open: absurd prompt length {n}");
        }
        let mut prompt = Vec::with_capacity(n);
        for _ in 0..n {
            prompt.push(read_varint(buf, &mut pos)? as i32);
        }
        // optional v7 tier tail (absent = tier 1)
        let tier = if pos < buf.len() {
            read_varint(buf, &mut pos)? as u32
        } else {
            1
        };
        // optional v8 device-profile tail behind the tier
        let profile = if pos < buf.len() {
            Some(DeviceProfileMsg::decode_from(buf, &mut pos)?)
        } else {
            None
        };
        if pos != buf.len() {
            bail!("open: trailing bytes");
        }
        Ok(OpenMsg {
            prompt,
            max_new,
            nonce,
            tier,
            profile,
        })
    }
}

/// Cloud → edge: the session is live.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpenAck {
    /// Server-assigned session id (used in every subsequent DraftMsg).
    pub session: u32,
    /// Target version sequence number currently deployed — lets the edge
    /// observe cloud-side evolution without ever receiving weights.
    pub target_seq: u64,
    /// Capability the edge replays in a `Resume` after a transport drop.
    pub resume_token: u64,
}

impl OpenAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24);
        write_u32(&mut out, self.session);
        write_varint(&mut out, self.target_seq);
        write_varint(&mut out, self.resume_token);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<OpenAck> {
        let mut pos = 0usize;
        let session = read_u32(buf, &mut pos)?;
        let target_seq = read_varint(buf, &mut pos)?;
        let resume_token = read_varint(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("open-ack: trailing bytes");
        }
        Ok(OpenAck {
            session,
            target_seq,
            resume_token,
        })
    }
}

/// Edge → cloud: reattach a parked session after a transport drop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResumeMsg {
    /// The `resume_token` from the session's `OpenAck`.
    pub token: u64,
    /// The edge's committed length (prompt + generated) — the position
    /// decoding continues from. The server replies with any committed
    /// tail beyond it (verdicts applied whose replies were lost).
    pub committed_len: u64,
}

impl ResumeMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20);
        write_varint(&mut out, self.token);
        write_varint(&mut out, self.committed_len);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ResumeMsg> {
        let mut pos = 0usize;
        let token = read_varint(buf, &mut pos)?;
        let committed_len = read_varint(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("resume: trailing bytes");
        }
        Ok(ResumeMsg {
            token,
            committed_len,
        })
    }
}

/// Cloud → edge: resume verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResumeAck {
    pub accepted: bool,
    /// True when the session already finished server-side while the link
    /// was down — `tail` completes it and no further drafting is needed.
    pub done: bool,
    /// Rejection class (wire v5, meaningful only when `!accepted`):
    /// true when the resume token is unknown or expired EVERYWHERE the
    /// cloud can see — the structured signal a fleet edge's re-root
    /// decision keys on (`EdgeSessionConfig::reroot_on_unknown_session`
    /// must not depend on parsing the human-readable `reason`). Only
    /// set on connections that negotiated v5; older peers always see
    /// the bit clear.
    pub unknown_token: bool,
    /// Server-assigned session id (0 when rejected).
    pub session: u32,
    /// Server-side committed length after applying `tail`.
    pub committed_len: u64,
    /// Server-side round count (the edge syncs its round counter so
    /// draft round numbers stay monotone across the reconnect).
    pub rounds: u64,
    /// Target version sequence currently deployed.
    pub target_seq: u64,
    /// Committed tokens the edge is missing (suffix beyond its reported
    /// position). Bounded: at most K+1 tokens per round lost in flight.
    pub tail: Vec<i32>,
    /// Human-readable rejection reason (empty when accepted).
    pub reason: String,
}

impl ResumeAck {
    pub fn rejected(reason: String) -> ResumeAck {
        ResumeAck {
            accepted: false,
            done: false,
            unknown_token: false,
            session: 0,
            committed_len: 0,
            rounds: 0,
            target_seq: 0,
            tail: Vec::new(),
            reason,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32 + self.tail.len() * 2 + self.reason.len());
        out.push(
            (self.accepted as u8)
                | ((self.done as u8) << 1)
                | ((self.unknown_token as u8) << 2),
        );
        write_u32(&mut out, self.session);
        write_varint(&mut out, self.committed_len);
        write_varint(&mut out, self.rounds);
        write_varint(&mut out, self.target_seq);
        write_varint(&mut out, self.tail.len() as u64);
        for &t in &self.tail {
            write_varint(&mut out, t as u64);
        }
        write_varint(&mut out, self.reason.len() as u64);
        out.extend_from_slice(self.reason.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ResumeAck> {
        let flags = *buf.first().ok_or_else(|| anyhow::anyhow!("resume-ack: empty"))?;
        if flags & !0b111 != 0 {
            bail!("resume-ack: bad flags byte {flags:#x}");
        }
        let mut pos = 1usize;
        let session = read_u32(buf, &mut pos)?;
        let committed_len = read_varint(buf, &mut pos)?;
        let rounds = read_varint(buf, &mut pos)?;
        let target_seq = read_varint(buf, &mut pos)?;
        let n = read_varint(buf, &mut pos)? as usize;
        if n > MAX_FRAME_BYTES {
            bail!("resume-ack: absurd tail length {n}");
        }
        let mut tail = Vec::with_capacity(n);
        for _ in 0..n {
            tail.push(read_varint(buf, &mut pos)? as i32);
        }
        let rn = read_varint(buf, &mut pos)? as usize;
        if pos + rn != buf.len() {
            bail!("resume-ack: reason length mismatch");
        }
        let reason = String::from_utf8(buf[pos..pos + rn].to_vec())?;
        Ok(ResumeAck {
            accepted: flags & 1 != 0,
            done: flags & 2 != 0,
            unknown_token: flags & 4 != 0,
            session,
            committed_len,
            rounds,
            target_seq,
            tail,
            reason,
        })
    }
}

/// Edge → cloud (wire v3): retract every in-flight speculative draft
/// round `>= round` for the stream's session. Sent when a verdict broke
/// the optimistic prefix those rounds were drafted from; the rounds are
/// redrafted from the true committed prefix under the SAME round
/// numbers. Idempotent and loss-tolerant: the cloud's basis check
/// discards stale drafts even when the `Cancel` never arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CancelMsg {
    /// First round to retract (everything at or beyond it is void).
    pub round: u32,
}

impl CancelMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4);
        write_u32(&mut out, self.round);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<CancelMsg> {
        let mut pos = 0usize;
        let round = read_u32(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("cancel: trailing bytes");
        }
        Ok(CancelMsg { round })
    }
}

/// Cloud → edge (wire v4): admission-control deferral for one draft
/// round. Sent INSTEAD of a `Verify` verdict when the cloud's bounded
/// pending-draft queue is full at submit time; the edge re-sends the
/// identical draft after `retry_after_ms` (exponential backoff on
/// repeat). Only emitted on connections that negotiated v4 — older
/// peers are always admitted, because a deferral they cannot parse
/// would strand their round forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BusyMsg {
    /// The deferred round (matches the draft's round number).
    pub round: u32,
    /// Suggested wait before retrying — the cloud's batching window, the
    /// horizon at which queue slots free up.
    pub retry_after_ms: u32,
}

impl BusyMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8);
        write_u32(&mut out, self.round);
        write_u32(&mut out, self.retry_after_ms);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<BusyMsg> {
        let mut pos = 0usize;
        let round = read_u32(buf, &mut pos)?;
        let retry_after_ms = read_u32(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("busy: trailing bytes");
        }
        Ok(BusyMsg {
            round,
            retry_after_ms,
        })
    }
}

/// Upper bound on a redirect target address (defensive: a hostile frame
/// must not allocate unbounded strings before validation).
pub const MAX_REDIRECT_ADDR: usize = 512;

/// Cloud → edge (wire v5): fleet session handoff. The session's state
/// was exported to the fleet's shared ledger; the edge should point its
/// next reattach at `addr` and replay the normal `Resume` handshake
/// with `resume_token` — the importing replica reconstructs the session
/// from the ledger and decoding continues from the committed prefix.
/// Loss-tolerant and duplicate-tolerant: the exporting replica keeps a
/// replay tombstone, re-imports if the edge resumes in place, and never
/// redirects the same session twice, so a lost, late, or duplicated
/// `Redirect` can never change a committed token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RedirectMsg {
    /// Peer replica to redial (a fleet address — TCP `host:port` or a
    /// registry label for in-process replicas).
    pub addr: String,
    /// Resume capability to replay there (the session's existing token;
    /// the ledger entry is keyed by it).
    pub resume_token: u64,
}

impl RedirectMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.addr.len());
        write_varint(&mut out, self.resume_token);
        write_varint(&mut out, self.addr.len() as u64);
        out.extend_from_slice(self.addr.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<RedirectMsg> {
        let mut pos = 0usize;
        let resume_token = read_varint(buf, &mut pos)?;
        let n = read_varint(buf, &mut pos)? as usize;
        if n > MAX_REDIRECT_ADDR {
            bail!("redirect: absurd address length {n}");
        }
        if pos + n != buf.len() {
            bail!("redirect: address length mismatch");
        }
        let addr = String::from_utf8(buf[pos..pos + n].to_vec())?;
        Ok(RedirectMsg { addr, resume_token })
    }
}

/// Cloud → edge (wire v5, control stream): one replica's telemetry,
/// announced after the handshake. `version` is the deployed target
/// version sequence (the same number `OpenAck::target_seq` carries);
/// `load` is the replica's instantaneous load (active sessions + drafts
/// pending verification). Purely informational on the wire — placement
/// decisions live in the fleet registry, which reads the same numbers
/// out-of-band.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaInfoMsg {
    /// Deployed target version sequence number.
    pub version: u64,
    /// Active sessions + pending drafts at announcement time.
    pub load: u32,
}

impl ReplicaInfoMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12);
        write_varint(&mut out, self.version);
        write_u32(&mut out, self.load);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<ReplicaInfoMsg> {
        let mut pos = 0usize;
        let version = read_varint(buf, &mut pos)?;
        let load = read_u32(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("replica-info: trailing bytes");
        }
        Ok(ReplicaInfoMsg { version, load })
    }
}

/// Edge → cloud (wire v6, control stream): metrics snapshot request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StatsMsg {
    /// Client-chosen nonce, echoed in the reply so multiple outstanding
    /// requests on one connection can be matched up.
    pub nonce: u64,
}

impl StatsMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(10);
        write_varint(&mut out, self.nonce);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<StatsMsg> {
        let mut pos = 0usize;
        let nonce = read_varint(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("stats: trailing bytes");
        }
        Ok(StatsMsg { nonce })
    }
}

/// Cloud → edge (wire v6, control stream): one replica's metrics
/// snapshot — headline serving counters plus the four mergeable latency
/// histograms ([`crate::obs::LatencySummary`]). Cheap on the wire: the
/// histograms use a sparse bucket encoding, so an idle replica answers
/// in tens of bytes. Purely informational — a fleet registry merges
/// these across replicas for fleet-wide percentiles.
#[derive(Debug, Clone)]
pub struct StatsAckMsg {
    /// Nonce echoed from the request.
    pub nonce: u64,
    /// Deployed target version sequence number.
    pub version: u64,
    /// Live sessions at snapshot time.
    pub sessions_active: u32,
    /// Sessions decoded to completion so far.
    pub sessions_completed: u64,
    /// Rounds verified so far.
    pub rounds: u64,
    /// Verification batches closed so far.
    pub batches: u64,
    /// Tokens committed so far.
    pub tokens_committed: u64,
    /// Latency histograms (round / queue / verify / rtt).
    pub latency: crate::obs::LatencySummary,
}

impl StatsAckMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(96);
        write_varint(&mut out, self.nonce);
        write_varint(&mut out, self.version);
        write_u32(&mut out, self.sessions_active);
        write_varint(&mut out, self.sessions_completed);
        write_varint(&mut out, self.rounds);
        write_varint(&mut out, self.batches);
        write_varint(&mut out, self.tokens_committed);
        self.latency.encode_into(&mut out);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<StatsAckMsg> {
        let mut pos = 0usize;
        let nonce = read_varint(buf, &mut pos)?;
        let version = read_varint(buf, &mut pos)?;
        let sessions_active = read_u32(buf, &mut pos)?;
        let sessions_completed = read_varint(buf, &mut pos)?;
        let rounds = read_varint(buf, &mut pos)?;
        let batches = read_varint(buf, &mut pos)?;
        let tokens_committed = read_varint(buf, &mut pos)?;
        let (latency, used) = crate::obs::LatencySummary::decode_from(&buf[pos..])?;
        pos += used;
        if pos != buf.len() {
            bail!("stats-ack: trailing bytes");
        }
        Ok(StatsAckMsg {
            nonce,
            version,
            sessions_active,
            sessions_completed,
            rounds,
            batches,
            tokens_committed,
            latency,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{DraftMsg, VerifyMsg, WireFormat};
    use crate::util::prop;

    fn draft_frame(rng: &mut crate::util::rng::SplitMix64) -> (DraftMsg, Frame) {
        let k = rng.next_range(8) as usize + 1;
        let stochastic = rng.chance(0.5);
        let speculative = rng.chance(0.35);
        let msg = DraftMsg {
            session: rng.next_u64() as u32,
            round: rng.next_range(10_000) as u32,
            tokens: (0..k).map(|_| rng.next_range(512) as i32).collect(),
            chosen_probs: if stochastic {
                (0..k).map(|_| rng.next_f64() as f32).collect()
            } else {
                vec![]
            },
            mode: if stochastic {
                VerifyMode::Stochastic
            } else {
                VerifyMode::Greedy
            },
            wire: WireFormat::Compact,
            // round-tagged speculative basis on a third of the drafts
            // (the v3 pipelined payload shape)
            basis_len: if speculative { rng.next_range(256) } else { 0 },
            spec: if speculative {
                (0..1 + rng.next_range(9)).map(|_| rng.next_range(512) as i32).collect()
            } else {
                vec![]
            },
            // ragged v8 tree topologies on a third of the drafts: each
            // node attaches to the committed prefix (0) or any earlier
            // node — combs, chains, and stars all come out of this
            tree: if rng.chance(0.35) {
                (0..k).map(|i| rng.next_range(i as u64 + 1) as u8).collect()
            } else {
                vec![]
            },
        };
        // stream ids from tiny to the full u32 range
        let stream = (rng.next_u64() as u32 >> (rng.next_range(31) as u32)).max(1);
        let frame = Frame::on(stream, FrameKind::Draft, msg.encode());
        (msg, frame)
    }

    #[test]
    fn frame_roundtrips_at_every_byte_split() {
        prop::check(40, |rng| {
            let (msg, frame) = draft_frame(rng);
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                if split < bytes.len() {
                    let early = dec.next_frame().map_err(|e| e.to_string())?;
                    prop::assert_prop(early.is_none(), format!("early frame at split {split}"))?;
                }
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f == frame, format!("frame mismatch at split {split}"))?;
                prop::assert_prop(
                    f.stream == frame.stream,
                    format!("stream id corrupted at split {split}"),
                )?;
                let back = DraftMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(
                    back.tokens == msg.tokens && back.session == msg.session,
                    "payload mismatch",
                )?;
                prop::assert_prop(
                    back.round == msg.round
                        && back.spec == msg.spec
                        && (msg.spec.is_empty() || back.basis_len == msg.basis_len),
                    format!("round/speculative-basis mismatch at split {split}"),
                )?;
                prop::assert_prop(
                    back.tree == msg.tree && back.n_leaves() == msg.n_leaves(),
                    format!("tree topology mismatch at split {split}"),
                )?;
                prop::assert_prop(
                    dec.next_frame().map_err(|e| e.to_string())?.is_none(),
                    "phantom trailing frame",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn vectored_head_plus_payload_equals_encode() {
        prop::check(40, |rng| {
            let (_, frame) = draft_frame(rng);
            let mut vectored = frame.encode_head().to_vec();
            vectored.extend_from_slice(&frame.payload);
            prop::assert_prop(vectored == frame.encode(), "head+payload != encode()")?;
            prop::assert_prop(
                frame.encoded_len() == frame.encode().len(),
                "encoded_len disagrees with encode().len()",
            )?;
            // A decoder fed the two vectored slices separately yields
            // the original frame — exactly what a writev-split send
            // produces on the wire.
            let mut dec = FrameDecoder::new();
            dec.push(&frame.encode_head());
            dec.push(&frame.payload);
            let back = dec
                .next_frame()
                .map_err(|e| e.to_string())?
                .ok_or("no frame from vectored slices")?;
            prop::assert_prop(back == frame, "vectored decode mismatch")?;
            Ok(())
        });
    }

    #[test]
    fn interleaved_multi_stream_decode_preserves_per_stream_order() {
        prop::check(40, |rng| {
            // 4 streams, several frames each, interleaved in random order
            // on ONE connection, pushed in random-sized chunks: global
            // order and per-stream sequences must both survive.
            const STREAMS: u32 = 4;
            let mut frames = Vec::new();
            let mut per_stream: Vec<Vec<VerifyMsg>> = vec![Vec::new(); STREAMS as usize];
            for i in 0..16u32 {
                let stream = 1 + rng.next_range(STREAMS as u64) as u32;
                let m = VerifyMsg {
                    session: stream, // sessions mirror streams here
                    round: i,
                    tau: rng.next_range(9) as u8,
                    correction: rng.next_range(512) as i32,
                    eos: rng.chance(0.2),
                    // v8 tree verdicts carry the winning leaf index
                    leaf: if rng.chance(0.3) {
                        Some(rng.next_range(12) as u8)
                    } else {
                        None
                    },
                };
                per_stream[(stream - 1) as usize].push(m.clone());
                frames.push(Frame::on(stream, FrameKind::Verify, m.encode()));
            }
            let mut wire = Vec::new();
            for f in &frames {
                wire.extend_from_slice(&f.encode());
            }
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            let mut demuxed: Vec<Vec<VerifyMsg>> = vec![Vec::new(); STREAMS as usize];
            let mut i = 0usize;
            while i < wire.len() {
                let n = (rng.next_range(11) as usize + 1).min(wire.len() - i);
                dec.push(&wire[i..i + n]);
                i += n;
                while let Some(f) = dec.next_frame().map_err(|e| e.to_string())? {
                    prop::assert_prop(
                        (1..=STREAMS).contains(&f.stream),
                        format!("stream {} out of range", f.stream),
                    )?;
                    demuxed[(f.stream - 1) as usize]
                        .push(VerifyMsg::decode(&f.payload).map_err(|e| e.to_string())?);
                    got.push(f);
                }
            }
            prop::assert_prop(got == frames, "interleaved global order diverged")?;
            prop::assert_prop(demuxed == per_stream, "per-stream demux diverged")?;
            prop::assert_prop(dec.pending_bytes() == 0, "leftover bytes")
        });
    }

    #[test]
    fn check_stream_rejects_zero_and_unknown_stream_ids() {
        let bound = |s: u32| s == 3 || s == 7;
        // control frames: stream 0 only
        assert!(check_stream(FrameKind::Hello, 0, bound).is_ok());
        assert!(check_stream(FrameKind::HelloAck, 0, bound).is_ok());
        assert!(check_stream(FrameKind::ReplicaInfo, 0, bound).is_ok());
        assert!(check_stream(FrameKind::Stats, 0, bound).is_ok());
        assert!(check_stream(FrameKind::StatsAck, 0, bound).is_ok());
        assert!(check_stream(FrameKind::Hello, 1, bound).is_err());
        assert!(check_stream(FrameKind::ReplicaInfo, 3, bound).is_err());
        assert!(check_stream(FrameKind::Stats, 3, bound).is_err());
        assert!(check_stream(FrameKind::StatsAck, 7, bound).is_err());
        // session frames: never stream 0
        for kind in [
            FrameKind::Open,
            FrameKind::OpenAck,
            FrameKind::Draft,
            FrameKind::Verify,
            FrameKind::Bye,
            FrameKind::Resume,
            FrameKind::ResumeAck,
            FrameKind::Cancel,
            FrameKind::Busy,
            FrameKind::Redirect,
        ] {
            assert!(check_stream(kind, 0, bound).is_err(), "{kind:?} on stream 0");
        }
        // stream-opening kinds may name fresh streams
        assert!(check_stream(FrameKind::Open, 99, bound).is_ok());
        assert!(check_stream(FrameKind::Resume, 99, bound).is_ok());
        // everything else must be bound
        assert!(check_stream(FrameKind::Draft, 3, bound).is_ok());
        assert!(check_stream(FrameKind::Verify, 7, bound).is_ok());
        assert!(check_stream(FrameKind::Cancel, 3, bound).is_ok());
        assert!(check_stream(FrameKind::Redirect, 3, bound).is_ok());
        assert!(check_stream(FrameKind::Draft, 99, bound).is_err());
        assert!(check_stream(FrameKind::Bye, 4, bound).is_err());
        assert!(check_stream(FrameKind::Cancel, 99, bound).is_err());
        assert!(check_stream(FrameKind::Redirect, 99, bound).is_err());

        // property: a random unknown stream is always rejected for
        // non-opening session kinds, and stream 0 for every session kind
        prop::check(60, |rng| {
            let s = rng.next_u64() as u32;
            let kind = match rng.next_range(6) {
                0 => FrameKind::Draft,
                1 => FrameKind::Verify,
                2 => FrameKind::Bye,
                3 => FrameKind::OpenAck,
                4 => FrameKind::Cancel,
                _ => FrameKind::ResumeAck,
            };
            let none_bound = |_: u32| false;
            prop::assert_prop(
                check_stream(kind, s, none_bound).is_err(),
                format!("{kind:?} accepted on unknown stream {s}"),
            )
        });
    }

    #[test]
    fn decoder_rejects_bad_length_and_kind() {
        let mut dec = FrameDecoder::new();
        dec.push(&[0, 0, 0, 0, 9]); // len 0 < FRAME_HEAD
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::new();
        dec.push(&[4, 0, 0, 0]); // len 4 < FRAME_HEAD (kind + stream)
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::new();
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        dec.push(&huge);
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::new();
        dec.push(&Frame::on(1, FrameKind::Bye, vec![]).encode());
        let mut bad = Frame::on(1, FrameKind::Bye, vec![]).encode();
        bad[4] = 200; // unknown kind, after a valid frame
        dec.push(&bad);
        assert_eq!(dec.next_frame().unwrap().unwrap().kind, FrameKind::Bye);
        assert!(dec.next_frame().is_err());
    }

    #[test]
    fn handshake_accepts_current_version() {
        let h = Hello {
            wire_version: WIRE_VERSION,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let back = Hello::decode(&h.encode()).unwrap();
        assert_eq!(back, h);
        let ack = hello_response(&back);
        assert!(ack.accepted);
        assert_eq!(HelloAck::decode(&ack.encode()).unwrap(), ack);
    }

    #[test]
    fn handshake_rejects_mismatched_wire_version() {
        let h = Hello {
            wire_version: WIRE_VERSION + 7,
            mode: VerifyMode::Stochastic,
            k_max: 4,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(!ack.accepted);
        assert!(ack.reason.contains("mismatch"), "{}", ack.reason);
        let wire = HelloAck::decode(&ack.encode()).unwrap();
        assert!(!wire.accepted);
        assert_eq!(wire.wire_version, WIRE_VERSION);
    }

    #[test]
    fn open_messages_roundtrip() {
        let o = OpenMsg {
            prompt: vec![1, 64, 127, 511, 3],
            max_new: 32,
            nonce: 0xDEAD_BEEF_CAFE,
            tier: 1,
            profile: None,
        };
        assert_eq!(OpenMsg::decode(&o.encode()).unwrap(), o);
        let a = OpenAck {
            session: 9,
            target_seq: 300,
            resume_token: u64::MAX - 17,
        };
        assert_eq!(OpenAck::decode(&a.encode()).unwrap(), a);
        assert!(OpenMsg::decode(&o.encode()[..3]).is_err());
    }

    #[test]
    fn open_tier_tail_is_optional_and_backward_compatible() {
        // default tier encodes NO tail: byte-identical to the v6 layout
        let default_tier = OpenMsg {
            prompt: vec![1, 70, 71],
            max_new: 16,
            nonce: 9,
            tier: 1,
            profile: None,
        };
        let bytes = default_tier.encode();
        let mut v6_bytes = Vec::new();
        super::super::codec::write_u32(&mut v6_bytes, 16);
        super::super::codec::write_varint(&mut v6_bytes, 9);
        super::super::codec::write_varint(&mut v6_bytes, 3);
        for t in [1u64, 70, 71] {
            super::super::codec::write_varint(&mut v6_bytes, t);
        }
        assert_eq!(bytes, v6_bytes, "tier 1 must not change the encoding");
        assert_eq!(OpenMsg::decode(&bytes).unwrap().tier, 1);
        // a priority tier rides the optional tail and round-trips
        let prio = OpenMsg {
            tier: 3,
            ..default_tier.clone()
        };
        let prio_bytes = prio.encode();
        assert!(prio_bytes.len() > bytes.len());
        assert_eq!(OpenMsg::decode(&prio_bytes).unwrap(), prio);
        // a tier tail followed by ONE byte is a truncated v8 profile
        let mut trailing = prio_bytes.clone();
        trailing.push(0x7F);
        assert!(OpenMsg::decode(&trailing).is_err());
    }

    #[test]
    fn open_device_profile_tail_is_optional_and_backward_compatible() {
        let plain = OpenMsg {
            prompt: vec![2, 80, 81, 300],
            max_new: 24,
            nonce: 41,
            tier: 1,
            profile: None,
        };
        let profile = DeviceProfileMsg {
            compute_tier: 2,
            channel_class: 1,
            energy_mj: 180_000,
        };
        // a profiled open roundtrips, at the default tier too (the tier
        // varint is forced so the tail stays unambiguous)
        for tier in [1u32, 3] {
            let o = OpenMsg {
                tier,
                profile: Some(profile),
                ..plain.clone()
            };
            assert_eq!(OpenMsg::decode(&o.encode()).unwrap(), o);
            // the profile rides strictly behind the v7 layout
            let v7 = OpenMsg { profile: None, tier, ..plain.clone() };
            assert!(o.encode().len() > v7.encode().len());
        }
        // absent profile at default tier: byte-identical to v6/v7, and
        // the profiled encoding is a strict extension of it
        let with = OpenMsg { profile: Some(profile), ..plain.clone() };
        let (pb, wb) = (plain.encode(), with.encode());
        assert_eq!(&wb[..pb.len()], &pb[..]);
        assert_eq!(wb[pb.len()], 1, "forced tier varint anchors the tail");
        // bad tier/class codes and truncations are rejected
        let mut bad = with.clone();
        bad.profile = Some(DeviceProfileMsg { compute_tier: 3, ..profile });
        assert!(OpenMsg::decode(&bad.encode()).is_err());
        let bytes = with.encode();
        for cut in plain.encode().len()..bytes.len() {
            assert!(OpenMsg::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn handshake_negotiates_v7_peer_below_tree_support() {
        // a v7 peer (pre-tree, pre-profile) is accepted; the agreed
        // version tells the edge it must send linear drafts with no
        // device profile, and the cloud never sends a leaf tail
        let h = Hello {
            wire_version: 7,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(ack.accepted);
        assert_eq!(ack.wire_version, 7);
    }

    #[test]
    fn resume_messages_roundtrip() {
        let r = ResumeMsg {
            token: 0x1234_5678_9ABC_DEF0,
            committed_len: 421,
        };
        assert_eq!(ResumeMsg::decode(&r.encode()).unwrap(), r);
        assert!(ResumeMsg::decode(&r.encode()[..1]).is_err());

        let live = ResumeAck {
            accepted: true,
            done: false,
            unknown_token: false,
            session: 7,
            committed_len: 24,
            rounds: 5,
            target_seq: 3,
            tail: vec![100, 205, 17],
            reason: String::new(),
        };
        assert_eq!(ResumeAck::decode(&live.encode()).unwrap(), live);

        let finished = ResumeAck {
            accepted: true,
            done: true,
            unknown_token: false,
            session: 7,
            committed_len: 30,
            rounds: 8,
            target_seq: 3,
            tail: vec![9, 9, 2],
            reason: String::new(),
        };
        assert_eq!(ResumeAck::decode(&finished.encode()).unwrap(), finished);

        let rejected = ResumeAck::rejected("unknown or expired resume token".into());
        let back = ResumeAck::decode(&rejected.encode()).unwrap();
        assert!(!back.accepted && !back.done && !back.unknown_token);
        assert!(back.reason.contains("expired"));

        // the structured rejection class (wire v5) survives the trip
        let mut lost = ResumeAck::rejected("session state lost fleet-wide".into());
        lost.unknown_token = true;
        let back = ResumeAck::decode(&lost.encode()).unwrap();
        assert!(!back.accepted && back.unknown_token);

        // flags byte with junk bits is rejected (guards against skew)
        let mut bytes = live.encode();
        bytes[0] |= 0b1000;
        assert!(ResumeAck::decode(&bytes).is_err());
    }

    #[test]
    fn handshake_negotiates_v2_downgrade() {
        // a v2 peer (pre-pipelining edge) is accepted and the ack tells
        // both sides the connection runs v2 — no Cancel, no spec tails
        let h = Hello {
            wire_version: MIN_WIRE_VERSION,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(ack.accepted);
        assert_eq!(ack.wire_version, MIN_WIRE_VERSION);
        let wire = HelloAck::decode(&ack.encode()).unwrap();
        assert_eq!(wire.wire_version, MIN_WIRE_VERSION);
        // below the floor is still rejected
        let old = Hello {
            wire_version: MIN_WIRE_VERSION - 1,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let nack = hello_response(&old);
        assert!(!nack.accepted);
        assert!(nack.reason.contains("mismatch"), "{}", nack.reason);
    }

    #[test]
    fn handshake_negotiates_v3_peer_below_busy_support() {
        // a v3 peer (pre-admission-control) is accepted; the agreed
        // version tells the cloud it must never send Busy frames there
        let h = Hello {
            wire_version: 3,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(ack.accepted);
        assert_eq!(ack.wire_version, 3);
    }

    #[test]
    fn busy_roundtrips_and_rejects_garbage() {
        let b = BusyMsg {
            round: 19,
            retry_after_ms: 12,
        };
        assert_eq!(BusyMsg::decode(&b.encode()).unwrap(), b);
        assert!(BusyMsg::decode(&b.encode()[..5]).is_err(), "truncated");
        let mut long = b.encode();
        long.push(0);
        assert!(BusyMsg::decode(&long).is_err(), "trailing bytes");
        assert_eq!(FrameKind::from_u8(11), Some(FrameKind::Busy));
        assert!(!FrameKind::Busy.is_control());
        assert!(!FrameKind::Busy.opens_stream());

        // framed + split at every byte, like every other session frame
        prop::check(20, |rng| {
            let msg = BusyMsg {
                round: rng.next_u64() as u32,
                retry_after_ms: rng.next_range(10_000) as u32,
            };
            let frame = Frame::on(
                1 + rng.next_u64() as u32 % 1000,
                FrameKind::Busy,
                msg.encode(),
            );
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f.kind == FrameKind::Busy, "kind survived")?;
                let back = BusyMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(back == msg, format!("busy mismatch at split {split}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn handshake_negotiates_v4_peer_below_redirect_support() {
        // a v4 peer (pre-fleet) is accepted; the agreed version tells
        // the cloud it must never send Redirect/ReplicaInfo frames there
        let h = Hello {
            wire_version: 4,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(ack.accepted);
        assert_eq!(ack.wire_version, 4);
    }

    #[test]
    fn redirect_roundtrips_and_rejects_garbage() {
        let r = RedirectMsg {
            addr: "replica-b:7412".into(),
            resume_token: 0x1234_5678_9ABC_DEF0,
        };
        assert_eq!(RedirectMsg::decode(&r.encode()).unwrap(), r);
        assert!(RedirectMsg::decode(&r.encode()[..3]).is_err(), "truncated");
        let mut long = r.encode();
        long.push(0);
        assert!(RedirectMsg::decode(&long).is_err(), "trailing bytes");
        // hostile length prefix is rejected before allocation
        let mut bogus = Vec::new();
        write_varint(&mut bogus, 7);
        write_varint(&mut bogus, (MAX_REDIRECT_ADDR + 1) as u64);
        assert!(RedirectMsg::decode(&bogus).is_err(), "absurd addr length");
        assert_eq!(FrameKind::from_u8(12), Some(FrameKind::Redirect));
        assert!(!FrameKind::Redirect.is_control());
        assert!(!FrameKind::Redirect.opens_stream());

        // framed + split at every byte, like every other session frame
        prop::check(20, |rng| {
            let msg = RedirectMsg {
                addr: format!("replica-{}:{}", rng.next_range(64), rng.next_range(65536)),
                resume_token: rng.next_u64(),
            };
            let frame = Frame::on(
                1 + rng.next_u64() as u32 % 1000,
                FrameKind::Redirect,
                msg.encode(),
            );
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f.kind == FrameKind::Redirect, "kind survived")?;
                let back = RedirectMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(back == msg, format!("redirect mismatch at split {split}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn replica_info_roundtrips_and_rejects_garbage() {
        let m = ReplicaInfoMsg {
            version: 17,
            load: 42,
        };
        assert_eq!(ReplicaInfoMsg::decode(&m.encode()).unwrap(), m);
        assert!(ReplicaInfoMsg::decode(&m.encode()[..2]).is_err(), "truncated");
        let mut long = m.encode();
        long.push(0);
        assert!(ReplicaInfoMsg::decode(&long).is_err(), "trailing bytes");
        assert_eq!(FrameKind::from_u8(13), Some(FrameKind::ReplicaInfo));
        assert!(FrameKind::ReplicaInfo.is_control(), "telemetry is control-scoped");
        assert!(!FrameKind::ReplicaInfo.opens_stream());

        prop::check(20, |rng| {
            let msg = ReplicaInfoMsg {
                version: rng.next_u64(),
                load: rng.next_range(100_000) as u32,
            };
            let frame = Frame::control(FrameKind::ReplicaInfo, msg.encode());
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f.stream == CONTROL_STREAM, "control stream survived")?;
                let back = ReplicaInfoMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(back == msg, format!("replica-info mismatch at split {split}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn handshake_negotiates_v5_peer_below_stats_support() {
        // a v5 peer (pre-stats) is accepted; the agreed version tells
        // both sides that Stats/StatsAck never flow on the connection
        let h = Hello {
            wire_version: 5,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(ack.accepted);
        assert_eq!(ack.wire_version, 5);
    }

    #[test]
    fn stats_messages_roundtrip_and_reject_garbage() {
        let s = StatsMsg { nonce: 0xFEED_F00D };
        assert_eq!(StatsMsg::decode(&s.encode()).unwrap(), s);
        let mut long = s.encode();
        long.push(0);
        assert!(StatsMsg::decode(&long).is_err(), "trailing bytes");
        assert_eq!(FrameKind::from_u8(14), Some(FrameKind::Stats));
        assert_eq!(FrameKind::from_u8(15), Some(FrameKind::StatsAck));
        assert!(FrameKind::Stats.is_control());
        assert!(FrameKind::StatsAck.is_control());
        assert!(!FrameKind::Stats.opens_stream());

        let mut latency = crate::obs::LatencySummary::new();
        for x in [1.5, 3.0, 120.0] {
            latency.round_ms.record(x);
        }
        latency.verify_ms.record(4.0);
        let ack = StatsAckMsg {
            nonce: 0xFEED_F00D,
            version: 3,
            sessions_active: 7,
            sessions_completed: 41,
            rounds: 900,
            batches: 310,
            tokens_committed: 4200,
            latency,
        };
        let back = StatsAckMsg::decode(&ack.encode()).unwrap();
        assert_eq!(back.nonce, ack.nonce);
        assert_eq!(back.version, 3);
        assert_eq!(back.sessions_active, 7);
        assert_eq!(back.sessions_completed, 41);
        assert_eq!(back.rounds, 900);
        assert_eq!(back.batches, 310);
        assert_eq!(back.tokens_committed, 4200);
        assert_eq!(back.latency.round_ms.count(), 3);
        assert_eq!(back.latency.round_ms.p50(), ack.latency.round_ms.p50());
        assert_eq!(back.latency.verify_ms.count(), 1);
        assert!(back.latency.queue_ms.is_empty());
        let mut long = ack.encode();
        long.push(0);
        assert!(StatsAckMsg::decode(&long).is_err(), "trailing bytes");
        // truncations never panic
        let bytes = ack.encode();
        for cut in 0..bytes.len() {
            assert!(StatsAckMsg::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }

        // framed + split at every byte, on the control stream
        prop::check(20, |rng| {
            let mut latency = crate::obs::LatencySummary::new();
            for _ in 0..rng.next_range(40) {
                latency.round_ms.record(10f64.powf(rng.next_f64() * 5.0 - 2.0));
            }
            let msg = StatsAckMsg {
                nonce: rng.next_u64(),
                version: rng.next_range(64),
                sessions_active: rng.next_range(1000) as u32,
                sessions_completed: rng.next_range(10_000),
                rounds: rng.next_range(100_000),
                batches: rng.next_range(50_000),
                tokens_committed: rng.next_range(1_000_000),
                latency,
            };
            let frame = Frame::control(FrameKind::StatsAck, msg.encode());
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f.stream == CONTROL_STREAM, "control stream survived")?;
                let back = StatsAckMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(
                    back.nonce == msg.nonce
                        && back.rounds == msg.rounds
                        && back.latency.round_ms.count() == msg.latency.round_ms.count(),
                    format!("stats-ack mismatch at split {split}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn cancel_roundtrips_and_rejects_garbage() {
        let c = CancelMsg { round: 7341 };
        assert_eq!(CancelMsg::decode(&c.encode()).unwrap(), c);
        assert!(CancelMsg::decode(&c.encode()[..3]).is_err(), "truncated");
        let mut long = c.encode();
        long.push(0);
        assert!(CancelMsg::decode(&long).is_err(), "trailing bytes");

        // framed + split at every byte, like every other session frame
        prop::check(20, |rng| {
            let msg = CancelMsg {
                round: rng.next_u64() as u32,
            };
            let frame = Frame::on(
                1 + rng.next_u64() as u32 % 1000,
                FrameKind::Cancel,
                msg.encode(),
            );
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f.kind == FrameKind::Cancel, "kind survived")?;
                let back = CancelMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(back == msg, format!("cancel mismatch at split {split}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn interleaved_drafts_and_cancels_demux_in_order() {
        // pipelined wire shape: per stream, Draft(r) / Draft(r+1, spec) /
        // Cancel(r+1) / Draft(r+1 redraft) interleaved across streams in
        // random global order and random chunking.
        prop::check(30, |rng| {
            const STREAMS: u32 = 3;
            let mut frames = Vec::new();
            for s in 1..=STREAMS {
                let base: Vec<i32> = (0..4).map(|_| rng.next_range(512) as i32).collect();
                let mk = |round: u32, spec: Vec<i32>| DraftMsg {
                    session: s,
                    round,
                    tokens: base.clone(),
                    chosen_probs: vec![],
                    mode: VerifyMode::Greedy,
                    wire: WireFormat::Compact,
                    basis_len: if spec.is_empty() { 0 } else { 11 },
                    spec,
                    tree: vec![],
                };
                frames.push(Frame::on(s, FrameKind::Draft, mk(0, vec![]).encode()));
                frames.push(Frame::on(
                    s,
                    FrameKind::Draft,
                    mk(1, base.iter().copied().chain([9]).collect()).encode(),
                ));
                frames.push(Frame::on(
                    s,
                    FrameKind::Cancel,
                    CancelMsg { round: 1 }.encode(),
                ));
                frames.push(Frame::on(s, FrameKind::Draft, mk(1, vec![]).encode()));
            }
            // shuffle across streams (stable per stream: sort-by random
            // key would break per-stream order, so interleave by rotation)
            let mut wire = Vec::new();
            let mut per_stream: Vec<std::collections::VecDeque<Frame>> =
                vec![Default::default(); STREAMS as usize];
            for f in frames.iter().cloned() {
                per_stream[(f.stream - 1) as usize].push_back(f);
            }
            let mut expect: Vec<Vec<Frame>> =
                per_stream.iter().map(|q| q.iter().cloned().collect()).collect();
            while per_stream.iter().any(|q| !q.is_empty()) {
                let s = rng.next_range(STREAMS as u64) as usize;
                if let Some(f) = per_stream[s].pop_front() {
                    wire.extend_from_slice(&f.encode());
                }
            }
            let mut dec = FrameDecoder::new();
            let mut got: Vec<Vec<Frame>> = vec![Vec::new(); STREAMS as usize];
            let mut i = 0usize;
            while i < wire.len() {
                let n = (rng.next_range(13) as usize + 1).min(wire.len() - i);
                dec.push(&wire[i..i + n]);
                i += n;
                while let Some(f) = dec.next_frame().map_err(|e| e.to_string())? {
                    got[(f.stream - 1) as usize].push(f);
                }
            }
            for s in 0..STREAMS as usize {
                prop::assert_prop(
                    got[s] == std::mem::take(&mut expect[s]),
                    format!("stream {} order diverged", s + 1),
                )?;
            }
            prop::assert_prop(dec.pending_bytes() == 0, "leftover bytes")
        });
    }

    #[test]
    fn resume_ack_roundtrips_at_every_byte_split() {
        prop::check(20, |rng| {
            let ack = ResumeAck {
                accepted: true,
                done: rng.chance(0.3),
                unknown_token: false,
                session: rng.next_u64() as u32,
                committed_len: rng.next_range(4096),
                rounds: rng.next_range(512),
                target_seq: rng.next_range(64),
                tail: (0..rng.next_range(9)).map(|_| rng.next_range(512) as i32).collect(),
                reason: String::new(),
            };
            let frame = Frame::on(
                1 + rng.next_u64() as u32 % 1000,
                FrameKind::ResumeAck,
                ack.encode(),
            );
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                let back = ResumeAck::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(back == ack, format!("resume-ack mismatch at split {split}"))?;
            }
            Ok(())
        });
    }
}
