//! Length-prefixed frame codec + wire-format version handshake for the
//! `serve` subsystem (real sockets, not the byte-accounting simulation).
//!
//! Every frame on the stream is `[len: u32 le][kind: u8][payload]` where
//! `len = 1 + payload.len()`. The codec is incremental (`FrameDecoder`
//! accepts arbitrary byte splits — TCP guarantees neither message
//! boundaries nor single-read delivery) and bounded (`MAX_FRAME_BYTES`
//! rejects hostile or corrupt length prefixes before allocation).
//!
//! Connection lifecycle:
//!
//! ```text
//! edge                      cloud
//!  Hello{wire_version} ───────▶     version gate (reject ≠ WIRE_VERSION)
//!       ◀─────── HelloAck{accepted}
//!  Open{prompt, max_new} ─────▶     KV session created
//!       ◀─────── OpenAck{session, target_seq}
//!  Draft{DraftMsg} ───────────▶     dynamic verification batcher
//!       ◀─────── Verify{VerifyMsg}
//!  ...                               (target hot-swaps never drop this)
//!  Bye ────────────────────────▶    session closed
//! ```

use super::codec::{read_u16, read_u32, read_varint, write_u16, write_u32, write_varint};
use super::VerifyMode;
use anyhow::{bail, Result};

/// Version of the frame layout + message payloads. Bump on any breaking
/// change; the handshake rejects mismatched peers instead of
/// misinterpreting their bytes.
pub const WIRE_VERSION: u16 = 1;

/// Upper bound on one frame's body (kind + payload). Prompts are ≤ a few
/// hundred tokens and draft blocks ≤ K_max tokens, so 1 MiB is generous.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Frame discriminator (first payload byte after the length prefix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameKind {
    /// Edge → cloud: wire-format version + verify mode announcement.
    Hello = 1,
    /// Cloud → edge: handshake verdict.
    HelloAck = 2,
    /// Edge → cloud: open a session (prompt + output budget).
    Open = 3,
    /// Cloud → edge: session id + current target version sequence.
    OpenAck = 4,
    /// Edge → cloud: one `DraftMsg` draft block.
    Draft = 5,
    /// Cloud → edge: one `VerifyMsg` verification verdict.
    Verify = 6,
    /// Edge → cloud: orderly end of session.
    Bye = 7,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        Some(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::HelloAck,
            3 => FrameKind::Open,
            4 => FrameKind::OpenAck,
            5 => FrameKind::Draft,
            6 => FrameKind::Verify,
            7 => FrameKind::Bye,
            _ => return None,
        })
    }
}

/// One wire frame: a kind tag + an opaque payload (message bytes).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn new(kind: FrameKind, payload: Vec<u8>) -> Frame {
        Frame { kind, payload }
    }

    /// `[len: u32 le][kind: u8][payload]`, len = 1 + payload.len().
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(5 + self.payload.len());
        write_u32(&mut out, (1 + self.payload.len()) as u32);
        out.push(self.kind as u8);
        out.extend_from_slice(&self.payload);
        out
    }
}

/// Incremental frame parser over an arbitrary byte stream.
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted lazily to amortize copies).
    off: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Feed raw bytes from the stream.
    pub fn push(&mut self, bytes: &[u8]) {
        // compact before growing if the dead prefix dominates
        if self.off > 4096 && self.off * 2 > self.buf.len() {
            self.buf.drain(..self.off);
            self.off = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet returned as a frame.
    pub fn pending_bytes(&self) -> usize {
        self.buf.len() - self.off
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>> {
        let avail = &self.buf[self.off..];
        if avail.len() < 4 {
            return Ok(None);
        }
        let mut pos = 0usize;
        let len = read_u32(avail, &mut pos)? as usize;
        if len == 0 || len > MAX_FRAME_BYTES {
            bail!("frame length {len} out of bounds (1..={MAX_FRAME_BYTES})");
        }
        if avail.len() < 4 + len {
            return Ok(None);
        }
        let kind = FrameKind::from_u8(avail[4])
            .ok_or_else(|| anyhow::anyhow!("unknown frame kind {}", avail[4]))?;
        let payload = avail[5..4 + len].to_vec();
        self.off += 4 + len;
        if self.off == self.buf.len() {
            self.buf.clear();
            self.off = 0;
        }
        Ok(Some(Frame { kind, payload }))
    }
}

// ---------------------------------------------------------------------
// Handshake + session-control message payloads
// ---------------------------------------------------------------------

/// Edge → cloud greeting: the first frame on every connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    pub wire_version: u16,
    pub mode: VerifyMode,
    /// Largest draft block this edge will ever send (informational).
    pub k_max: u8,
}

impl Hello {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4);
        write_u16(&mut out, self.wire_version);
        out.push(match self.mode {
            VerifyMode::Greedy => 0,
            VerifyMode::Stochastic => 1,
        });
        out.push(self.k_max);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Hello> {
        let mut pos = 0usize;
        let wire_version = read_u16(buf, &mut pos)?;
        let mode = match buf.get(pos) {
            Some(0) => VerifyMode::Greedy,
            Some(1) => VerifyMode::Stochastic,
            _ => bail!("hello: bad mode byte"),
        };
        pos += 1;
        let k_max = *buf.get(pos).ok_or_else(|| anyhow::anyhow!("hello: truncated"))?;
        pos += 1;
        if pos != buf.len() {
            bail!("hello: trailing bytes");
        }
        Ok(Hello {
            wire_version,
            mode,
            k_max,
        })
    }
}

/// Cloud → edge handshake verdict.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HelloAck {
    pub wire_version: u16,
    pub accepted: bool,
    /// Human-readable rejection reason (empty when accepted).
    pub reason: String,
}

impl HelloAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.reason.len());
        write_u16(&mut out, self.wire_version);
        out.push(self.accepted as u8);
        write_varint(&mut out, self.reason.len() as u64);
        out.extend_from_slice(self.reason.as_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<HelloAck> {
        let mut pos = 0usize;
        let wire_version = read_u16(buf, &mut pos)?;
        let accepted = match buf.get(pos) {
            Some(0) => false,
            Some(1) => true,
            _ => bail!("hello-ack: bad accepted byte"),
        };
        pos += 1;
        let n = read_varint(buf, &mut pos)? as usize;
        if pos + n != buf.len() {
            bail!("hello-ack: reason length mismatch");
        }
        let reason = String::from_utf8(buf[pos..pos + n].to_vec())?;
        Ok(HelloAck {
            wire_version,
            accepted,
            reason,
        })
    }
}

/// The cloud's answer to a `Hello`: the single place the version gate
/// lives, so the simulator-side tests and the server agree on it.
pub fn hello_response(h: &Hello) -> HelloAck {
    if h.wire_version == WIRE_VERSION {
        HelloAck {
            wire_version: WIRE_VERSION,
            accepted: true,
            reason: String::new(),
        }
    } else {
        HelloAck {
            wire_version: WIRE_VERSION,
            accepted: false,
            reason: format!(
                "wire version mismatch: peer speaks v{}, this cloud speaks v{}",
                h.wire_version, WIRE_VERSION
            ),
        }
    }
}

/// Edge → cloud: open one serving session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpenMsg {
    pub prompt: Vec<i32>,
    pub max_new: u32,
}

impl OpenMsg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.prompt.len() * 2);
        write_u32(&mut out, self.max_new);
        write_varint(&mut out, self.prompt.len() as u64);
        for &t in &self.prompt {
            write_varint(&mut out, t as u64);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<OpenMsg> {
        let mut pos = 0usize;
        let max_new = read_u32(buf, &mut pos)?;
        let n = read_varint(buf, &mut pos)? as usize;
        if n > MAX_FRAME_BYTES {
            bail!("open: absurd prompt length {n}");
        }
        let mut prompt = Vec::with_capacity(n);
        for _ in 0..n {
            prompt.push(read_varint(buf, &mut pos)? as i32);
        }
        if pos != buf.len() {
            bail!("open: trailing bytes");
        }
        Ok(OpenMsg { prompt, max_new })
    }
}

/// Cloud → edge: the session is live.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpenAck {
    /// Server-assigned session id (used in every subsequent DraftMsg).
    pub session: u32,
    /// Target version sequence number currently deployed — lets the edge
    /// observe cloud-side evolution without ever receiving weights.
    pub target_seq: u64,
}

impl OpenAck {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12);
        write_u32(&mut out, self.session);
        write_varint(&mut out, self.target_seq);
        out
    }

    pub fn decode(buf: &[u8]) -> Result<OpenAck> {
        let mut pos = 0usize;
        let session = read_u32(buf, &mut pos)?;
        let target_seq = read_varint(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("open-ack: trailing bytes");
        }
        Ok(OpenAck {
            session,
            target_seq,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::{DraftMsg, VerifyMsg, WireFormat};
    use crate::util::prop;

    fn draft_frame(rng: &mut crate::util::rng::SplitMix64) -> (DraftMsg, Frame) {
        let k = rng.next_range(8) as usize + 1;
        let stochastic = rng.chance(0.5);
        let msg = DraftMsg {
            session: rng.next_u64() as u32,
            round: rng.next_range(10_000) as u32,
            tokens: (0..k).map(|_| rng.next_range(512) as i32).collect(),
            chosen_probs: if stochastic {
                (0..k).map(|_| rng.next_f64() as f32).collect()
            } else {
                vec![]
            },
            mode: if stochastic {
                VerifyMode::Stochastic
            } else {
                VerifyMode::Greedy
            },
            wire: WireFormat::Compact,
        };
        let frame = Frame::new(FrameKind::Draft, msg.encode());
        (msg, frame)
    }

    #[test]
    fn frame_roundtrips_at_every_byte_split() {
        prop::check(40, |rng| {
            let (msg, frame) = draft_frame(rng);
            let bytes = frame.encode();
            for split in 0..=bytes.len() {
                let mut dec = FrameDecoder::new();
                dec.push(&bytes[..split]);
                if split < bytes.len() {
                    let early = dec.next_frame().map_err(|e| e.to_string())?;
                    prop::assert_prop(early.is_none(), format!("early frame at split {split}"))?;
                }
                dec.push(&bytes[split..]);
                let f = dec
                    .next_frame()
                    .map_err(|e| e.to_string())?
                    .ok_or("no frame after full input")?;
                prop::assert_prop(f == frame, format!("frame mismatch at split {split}"))?;
                let back = DraftMsg::decode(&f.payload).map_err(|e| e.to_string())?;
                prop::assert_prop(
                    back.tokens == msg.tokens && back.session == msg.session,
                    "payload mismatch",
                )?;
                prop::assert_prop(
                    dec.next_frame().map_err(|e| e.to_string())?.is_none(),
                    "phantom trailing frame",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn verify_frames_roundtrip_through_concatenated_stream() {
        prop::check(40, |rng| {
            // several frames back to back, pushed in random-sized chunks
            let msgs: Vec<VerifyMsg> = (0..4)
                .map(|i| VerifyMsg {
                    session: i,
                    round: rng.next_range(100) as u32,
                    tau: rng.next_range(9) as u8,
                    correction: rng.next_range(512) as i32,
                    eos: rng.chance(0.2),
                })
                .collect();
            let mut stream = Vec::new();
            for m in &msgs {
                stream.extend_from_slice(&Frame::new(FrameKind::Verify, m.encode()).encode());
            }
            let mut dec = FrameDecoder::new();
            let mut got = Vec::new();
            let mut i = 0usize;
            while i < stream.len() {
                let n = (rng.next_range(7) as usize + 1).min(stream.len() - i);
                dec.push(&stream[i..i + n]);
                i += n;
                while let Some(f) = dec.next_frame().map_err(|e| e.to_string())? {
                    prop::assert_prop(f.kind == FrameKind::Verify, "wrong kind")?;
                    got.push(VerifyMsg::decode(&f.payload).map_err(|e| e.to_string())?);
                }
            }
            prop::assert_prop(got == msgs, "stream decode mismatch")?;
            prop::assert_prop(dec.pending_bytes() == 0, "leftover bytes")
        });
    }

    #[test]
    fn decoder_rejects_bad_length_and_kind() {
        let mut dec = FrameDecoder::new();
        dec.push(&[0, 0, 0, 0, 9]); // len 0
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::new();
        let huge = (MAX_FRAME_BYTES as u32 + 1).to_le_bytes();
        dec.push(&huge);
        assert!(dec.next_frame().is_err());

        let mut dec = FrameDecoder::new();
        dec.push(&Frame::new(FrameKind::Bye, vec![]).encode());
        let mut bad = Frame::new(FrameKind::Bye, vec![]).encode();
        bad[4] = 200; // unknown kind, after a valid frame
        dec.push(&bad);
        assert_eq!(dec.next_frame().unwrap().unwrap().kind, FrameKind::Bye);
        assert!(dec.next_frame().is_err());
    }

    #[test]
    fn handshake_accepts_current_version() {
        let h = Hello {
            wire_version: WIRE_VERSION,
            mode: VerifyMode::Greedy,
            k_max: 8,
        };
        let back = Hello::decode(&h.encode()).unwrap();
        assert_eq!(back, h);
        let ack = hello_response(&back);
        assert!(ack.accepted);
        assert_eq!(HelloAck::decode(&ack.encode()).unwrap(), ack);
    }

    #[test]
    fn handshake_rejects_mismatched_wire_version() {
        let h = Hello {
            wire_version: WIRE_VERSION + 7,
            mode: VerifyMode::Stochastic,
            k_max: 4,
        };
        let ack = hello_response(&Hello::decode(&h.encode()).unwrap());
        assert!(!ack.accepted);
        assert!(ack.reason.contains("mismatch"), "{}", ack.reason);
        let wire = HelloAck::decode(&ack.encode()).unwrap();
        assert!(!wire.accepted);
        assert_eq!(wire.wire_version, WIRE_VERSION);
    }

    #[test]
    fn open_messages_roundtrip() {
        let o = OpenMsg {
            prompt: vec![1, 64, 127, 511, 3],
            max_new: 32,
        };
        assert_eq!(OpenMsg::decode(&o.encode()).unwrap(), o);
        let a = OpenAck {
            session: 9,
            target_seq: 300,
        };
        assert_eq!(OpenAck::decode(&a.encode()).unwrap(), a);
        assert!(OpenMsg::decode(&o.encode()[..3]).is_err());
    }
}
