//! Report rendering: collect experiment tables into a markdown report
//! (EXPERIMENTS-results.md) and print them to the terminal.

use crate::experiments::{all_experiments, Ctx, Experiment};
use crate::util::table::Table;
use anyhow::Result;
use std::path::Path;
use std::time::Instant;

pub struct ReportEntry {
    pub id: String,
    pub title: String,
    pub tables: Vec<Table>,
    pub secs: f64,
}

pub fn run_experiments(ctx: &Ctx, ids: &[String]) -> Result<Vec<ReportEntry>> {
    let exps: Vec<Experiment> = if ids.len() == 1 && ids[0] == "all" {
        all_experiments()
    } else {
        let mut out = Vec::new();
        for id in ids {
            out.push(
                crate::experiments::find(id)
                    .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}' (try `flexspec list`)"))?,
            );
        }
        out
    };

    let mut entries = Vec::new();
    for e in exps {
        eprintln!("== running {} — {}", e.id, e.title);
        let t0 = Instant::now();
        let tables = (e.run)(ctx)?;
        let secs = t0.elapsed().as_secs_f64();
        for t in &tables {
            println!("\n{}", t.render());
        }
        eprintln!("== {} done in {:.1}s", e.id, secs);
        entries.push(ReportEntry {
            id: e.id.to_string(),
            title: e.title.to_string(),
            tables,
            secs,
        });
    }
    Ok(entries)
}

pub fn write_markdown(entries: &[ReportEntry], path: &Path, header: &str) -> Result<()> {
    let mut out = String::new();
    out.push_str(header);
    for e in entries {
        out.push_str(&format!("\n## {} — {} ({:.1}s)\n\n", e.id, e.title, e.secs));
        for t in &e.tables {
            out.push_str(&t.render_markdown());
            out.push('\n');
        }
    }
    std::fs::write(path, out)?;
    eprintln!("report written to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::table::Table;

    #[test]
    fn markdown_report_roundtrip() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into()]);
        let entries = vec![ReportEntry {
            id: "x".into(),
            title: "t".into(),
            tables: vec![t],
            secs: 0.5,
        }];
        let dir = std::env::temp_dir().join("flexspec_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("r.md");
        write_markdown(&entries, &p, "# hdr\n").unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.contains("# hdr") && text.contains("| a |"));
    }
}
