//! CLI entry point: `flexspec <command> [options]`.
//!
//! Commands:
//!   list                         list experiments
//!   exp <id|all> [--requests N] [--seed S] [--report path.md]
//!   serve [--users N] [--network 5g|4g|wifi] [--window MS] ...
//!   serve-cloud [--bind H:P] [--backend synthetic|engine] [--sessions N]
//!   serve-edge  [--addr H:P] [--sessions N] [--draft synthetic|pld]
//!   loadgen <scenario> [--sessions N] [--replicas N] [--seed S] ...
//!   info                         artifact + model zoo inventory
//!   trace <5g|4g|wifi> <out.csv> [--samples N]

use crate::channel::{ChannelTrace, NetworkKind, NetworkProfile};
use crate::coordinator::edge::DraftSource;
use crate::coordinator::{serve, CloudEngine, ServeConfig};
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::experiments::Ctx;
use crate::obs::{LatencySummary, Trace, VirtualClock};
use crate::serve::transport::BoxFuture;
use crate::serve::{
    run_edge_session, run_session_on, serve_cloud, BatchMode, EdgeMux, EdgeReport,
    EdgeSessionConfig, EngineBackend, FaultConfig, FaultPlan, Reconnect, ResumableTransport,
    SyntheticDraft, SyntheticTarget, TcpTransport, Transport, VerifierConfig, VerifyBackend,
};
use crate::util::cli::Args;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

const VALUE_OPTS: &[&str] = &[
    "requests", "seed", "report", "users", "network", "window", "max-batch", "batch-mode",
    "max-new", "dataset", "samples", "arrival-ms", "artifacts",
    "bind", "addr", "backend", "sessions", "k", "draft", "version",
    "deploy-version", "deploy-after", "resume-grace", "fault-seed",
    "fault-disconnects", "pipeline-depth", "admission-queue", "tier-weights",
    "fleet", "canary", "drain-after", "fleet-addrs",
    "metrics-json", "trace", "log-level", "replicas", "network-mix",
    "autoscale-tick", "min-replicas", "max-replicas", "scale-up-queue",
    "scale-down-queue", "redirect-budget", "action-log", "tier-reserve",
    "ledger-ttl", "staleness",
];

/// `--batch-mode window|continuous` (default: the windowed batcher).
fn batch_mode_from(args: &Args) -> Result<BatchMode> {
    let s = args.get_or("batch-mode", "window");
    BatchMode::parse(&s)
        .ok_or_else(|| anyhow::anyhow!("bad --batch-mode '{s}' (window|continuous)"))
}

/// The `--autoscale` knob family → a policy config. Shared by `loadgen
/// --autoscale` (sim twin) and `serve-cloud --fleet N --autoscale`
/// (live controller) so the SAME flags drive both sides of the
/// determinism contract. `initial` seeds `--min-replicas` — by default
/// the autoscaler never shrinks below the fleet it started with.
fn autoscale_config_from(args: &Args, initial: usize) -> crate::autoscale::AutoscaleConfig {
    let d = crate::autoscale::AutoscaleConfig::default();
    let min_replicas = args.get_usize("min-replicas", initial).max(1);
    crate::autoscale::AutoscaleConfig {
        tick_ms: args.get_f64("autoscale-tick", d.tick_ms).max(1.0),
        min_replicas,
        max_replicas: args.get_usize("max-replicas", d.max_replicas).max(min_replicas),
        scale_up_queue: args.get_usize("scale-up-queue", d.scale_up_queue),
        scale_down_queue: args.get_usize("scale-down-queue", d.scale_down_queue),
        redirect_budget: args
            .get_usize("redirect-budget", d.redirect_budget as usize)
            .min(u8::MAX as usize) as u8,
        staleness_ms: args.get_f64("staleness", d.staleness_ms).max(1.0),
        ..d
    }
}

/// One `tick action` line per control decision plus a trailing digest
/// comment — `loadgen --action-log` and the fleet controller's export
/// share this format, so diffing the two files IS the byte-identity
/// check.
fn write_action_log(path: &str, lines: &[String], digest: u64) -> Result<()> {
    let mut out = lines.join("\n");
    if !lines.is_empty() {
        out.push('\n');
    }
    out.push_str(&format!("# log_digest {digest:016x}\n"));
    std::fs::write(path, out)?;
    Ok(())
}

pub fn cli_main() -> Result<()> {
    let args = Args::from_env(VALUE_OPTS);
    if let Some(dir) = args.get("artifacts") {
        std::env::set_var("FLEXSPEC_ARTIFACTS", dir);
    }
    if args.flag("verbose") {
        crate::util::log::set_level(crate::util::log::Level::Debug);
    }
    // --log-level beats --verbose when both are given
    if let Some(lv) = args.get("log-level") {
        let Some(level) = crate::util::log::Level::parse(&lv) else {
            bail!("bad --log-level '{lv}' (error|warn|info|debug)");
        };
        crate::util::log::set_level(level);
    }
    match args.positional(0) {
        Some("list") => {
            println!("experiments:");
            for e in crate::experiments::all_experiments() {
                println!("  {:8} {}", e.id, e.title);
            }
            Ok(())
        }
        Some("info") => info(),
        Some("exp") => exp(&args),
        Some("serve") => serve_cmd(&args),
        Some("serve-cloud") => serve_cloud_cmd(&args),
        Some("serve-edge") => serve_edge_cmd(&args),
        Some("loadgen") => loadgen_cmd(&args),
        Some("trace") => trace_cmd(&args),
        _ => {
            println!(
                "FlexSpec reproduction — usage:\n\
                 \x20 flexspec list\n\
                 \x20 flexspec info\n\
                 \x20 flexspec exp <id|all> [--requests N] [--seed S] [--report out.md]\n\
                 \x20 flexspec serve [--users N] [--network 5g|4g|wifi] [--window MS]\n\
                 \x20 flexspec serve-cloud [--bind 127.0.0.1:7411] [--backend synthetic|engine]\n\
                 \x20\x20\x20\x20 [--sessions N] [--window MS] [--max-batch N] [--seed S]\n\
                 \x20\x20\x20\x20 [--batch-mode window|continuous]  (rolling slot admission, docs/BATCHING.md)\n\
                 \x20\x20\x20\x20 [--admission-queue N]  (pending-draft bound; 0=unbounded,\n\
                 \x20\x20\x20\x20\x20 effective values 1..max-batch — the window drains at max-batch)\n\
                 \x20\x20\x20\x20 [--resume-grace MS] [--deploy-version NAME --deploy-after N]\n\
                 \x20\x20\x20\x20 [--tier-reserve N]  (admission slots held back for QoS tier > 1, wire v7)\n\
                 \x20\x20\x20\x20 [--ledger-ttl MS]  (handoff-ledger entry TTL; abandoned exports expire)\n\
                 \x20\x20\x20\x20 [--fleet N]  (N replicas on consecutive ports, shared handoff ledger)\n\
                 \x20\x20\x20\x20 [--canary K]  (staged rollout: deploy-version goes to K replicas first)\n\
                 \x20\x20\x20\x20 [--drain-after M]  (drain replica 0 to replica 1 after M sessions)\n\
                 \x20\x20\x20\x20 [--autoscale]  (closed-loop fleet sizing; see autoscale knobs below)\n\
                 \x20 flexspec serve-edge [--addr 127.0.0.1:7411] [--sessions N] [--max-new N]\n\
                 \x20\x20\x20\x20 [--draft synthetic|pld] [--k K|0=adaptive] [--seed S]\n\
                 \x20\x20\x20\x20 [--mux] [--tier-weights 3,1,...] [--fault-seed S] [--fault-disconnects N]\n\
                 \x20\x20\x20\x20 [--pipeline-depth D]  (1=sequential, >=2 pipelined, 0=auto policy)\n\
                 \x20\x20\x20\x20 [--fleet-addrs a:p,b:p,...]  (follow Redirects, fail over, re-root)\n\
                 \x20 flexspec loadgen <steady|flash|diurnal|churn|hetero> [--sessions N] [--seed S]\n\
                 \x20\x20\x20\x20 [--replicas N] [--window MS] [--max-batch N] [--k K]\n\
                 \x20\x20\x20\x20 [--batch-mode window|continuous]\n\
                 \x20\x20\x20\x20 [--admission-queue N] [--network-mix 5g|4g|wifi|W5,W4,Ww]\n\
                 \x20\x20\x20\x20 [--device-mix eval|strong|Ww,Wm,Ws] [--branching B]\n\
                 \x20\x20\x20\x20\x20\x20 (heterogeneous tiers + tree speculation, wire v8; docs/HETERO.md)\n\
                 \x20\x20\x20\x20 [--autoscale]  (run the control loop's sim twin; docs/AUTOSCALE.md)\n\
                 \x20\x20\x20\x20 [--selfcheck]  (run twice, assert byte-identical digests)\n\
                 \x20\x20\x20\x20 fleet-scale virtual-clock workload (docs/LOADGEN.md)\n\
                 Autoscale knobs (loadgen --autoscale / serve-cloud --fleet N --autoscale):\n\
                 \x20\x20\x20\x20 [--autoscale-tick MS] [--min-replicas N] [--max-replicas N]\n\
                 \x20\x20\x20\x20 [--scale-up-queue D] [--scale-down-queue D] [--redirect-budget N]\n\
                 \x20\x20\x20\x20 [--staleness MS] [--action-log out.log]  (tick+action lines, FNV digest)\n\
                 \x20 flexspec trace <5g|4g|wifi> <out.csv> [--samples N]\n\
                 Observability (serve / serve-cloud / serve-edge / loadgen):\n\
                 \x20\x20\x20\x20 [--trace out.jsonl]       per-round span journal (JSONL)\n\
                 \x20\x20\x20\x20 [--metrics-json out.json] counters + latency histograms\n\
                 \x20\x20\x20\x20 [--log-level error|warn|info|debug]\n\
                 Run `make artifacts` first to build the AOT model zoo."
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let reg = crate::runtime::Registry::open_default()?;
    let m = &reg.manifest;
    println!("artifacts: {}", m.root.display());
    println!("block={} k_max={} prefill_chunk={}", m.block, m.k_max, m.prefill_chunk);
    println!("\narchitectures:");
    for (name, a) in &m.archs {
        println!(
            "  {:24} vocab={:5} d={} L={} heads={} ff={} experts={} lora_r={} params={}",
            name, a.vocab, a.d_model, a.n_layers, a.n_heads, a.d_ff, a.n_experts, a.lora_rank,
            a.n_params()
        );
    }
    println!("\nweight bundles:");
    for (name, w) in &m.weights {
        println!("  {:36} kind={:13} arch={}", name, w.kind, w.arch);
    }
    if !m.calibration.is_empty() {
        println!("\nbuild-time acceptance calibration:");
        for (k, v) in &m.calibration {
            println!("  {k:32} {v:.3}");
        }
    }
    Ok(())
}

fn exp(args: &Args) -> Result<()> {
    let ids: Vec<String> = if args.positional.len() > 1 {
        args.positional[1..].to_vec()
    } else {
        vec!["all".to_string()]
    };
    let requests = args.get_usize("requests", 6);
    let seed = args.get_u64("seed", 7);
    let mut ctx = Ctx::open(requests, seed)?;
    ctx.verbose = args.flag("verbose");
    let entries = crate::report::run_experiments(&ctx, &ids)?;
    if let Some(path) = args.get("report") {
        let header = format!(
            "# FlexSpec reproduction — experiment results\n\n\
             requests/cell = {requests}, seed = {seed}. Regenerate with\n\
             `cargo run --release -- exp all --requests {requests} --seed {seed} --report <path>`.\n"
        );
        crate::report::write_markdown(&entries, &PathBuf::from(path), &header)?;
    }
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let reg = crate::runtime::Registry::open_default()?;
    let network = NetworkKind::parse(&args.get_or("network", "4g"))
        .ok_or_else(|| anyhow::anyhow!("bad --network"))?;
    let users = args.get_usize("users", 8);
    let dataset = args.get_or("dataset", "mtbench");
    let mut gen = crate::workload::WorkloadGen::new(&dataset, args.get_u64("seed", 1))?;
    let prompts: Vec<Vec<i32>> = gen.take(users).into_iter().map(|r| r.prompt).collect();

    let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", crate::workload::EOS)?;
    let draft = reg.model("draft_flex_llama2t")?;
    let cfg = ServeConfig {
        users,
        window_ms: args.get_f64("window", 12.0),
        max_batch: args.get_usize("max-batch", 8),
        max_new: args.get_usize("max-new", 32),
        arrival_mean_ms: args.get_f64("arrival-ms", 300.0),
        seed: args.get_u64("seed", 1),
        // pipelining needs a pure draft source; the PJRT model draft
        // falls back to sequential (see ServeConfig::pipeline_depth)
        pipeline_depth: args.get_usize("pipeline-depth", 1),
        admission_queue: args.get_usize("admission-queue", 0),
        // the simulator journals on its own virtual clock; event
        // timestamps in the JSONL are virtual ms
        trace: args.get("trace").map(|_| Trace::new(VirtualClock::shared())),
        ..Default::default()
    };
    let trace = cfg.trace.clone();
    let net = NetworkProfile::new(network);
    let rep = serve(&mut cloud, draft, &prompts, &JETSON_ORIN, &A800_70B, &net, &cfg)?;
    println!("served {} sessions on {} ({} dataset)", rep.completed, network.label(), dataset);
    println!("  tokens           {}", rep.tokens);
    println!("  wall time        {:.1} ms (virtual)", rep.wall_ms);
    println!("  throughput       {:.1} tok/s", rep.throughput_tok_s());
    println!("  mean batch size  {:.2} ({} batches)", rep.mean_batch, rep.batches);
    println!("  T_base amortized {:.0} ms saved", rep.t_base_saved_ms);
    println!("  busy deferrals   {}", rep.drafts_busy_deferred);
    println!("  request latency  p50 {:.0} ms  p95 {:.0} ms", rep.request_latency.p50(), rep.request_latency.p95());
    println!("  per-token        p50 {:.0} ms  p95 {:.0} ms", rep.per_token_latency.p50(), rep.per_token_latency.p95());
    println!("  acceptance       {:.2}", rep.acceptance.mean());
    print!("{}", rep.latency.render_lines("  "));
    if let Some(path) = args.get("metrics-json") {
        use crate::util::json::Json;
        let j = Json::obj(vec![
            ("sessions", Json::Num(rep.completed as f64)),
            ("tokens", Json::Num(rep.tokens as f64)),
            ("rounds", Json::Num(rep.rounds as f64)),
            ("batches", Json::Num(rep.batches as f64)),
            ("wall_ms", Json::Num(rep.wall_ms)),
            ("latency", rep.latency.to_json()),
        ]);
        std::fs::write(&path, j.to_string_pretty())?;
        println!("wrote metrics to {path}");
    }
    if let (Some(tr), Some(path)) = (&trace, args.get("trace")) {
        tr.write_jsonl(&path)?;
        println!("wrote {} trace events to {path}", tr.len());
    }
    Ok(())
}

/// `serve-cloud`: run the TCP verification server.
///
/// Backends: `synthetic` (deterministic, artifact-free; versions
/// `synthetic_base` / `gsm8k_lora` / `nq_lora` / `code_full` with
/// increasing drift) or `engine` (PJRT model zoo, needs `make
/// artifacts`). With `--sessions N` the server shuts down gracefully
/// after N sessions complete; with `--deploy-version V --deploy-after
/// M` it hot-swaps the target once M sessions finished — live sessions
/// keep decoding.
fn serve_cloud_cmd(args: &Args) -> Result<()> {
    let fleet = args.get_usize("fleet", 1);
    if fleet > 1 {
        return serve_fleet_cmd(args, fleet);
    }
    let bind = args.get_or("bind", "127.0.0.1:7411");
    let backend_kind = args.get_or("backend", "synthetic");
    let seed = args.get_u64("seed", 1);
    let trace = args.get("trace").map(|_| Trace::wall());
    let d = VerifierConfig::default();
    let vcfg = VerifierConfig {
        window_ms: args.get_f64("window", 12.0),
        max_batch: args.get_usize("max-batch", 8),
        batch_mode: batch_mode_from(args)?,
        seed,
        resume_grace_ms: args.get_f64("resume-grace", 10_000.0),
        admission_queue: args.get_usize("admission-queue", 0),
        tier_reserve: args.get_usize("tier-reserve", d.tier_reserve),
        ledger_ttl_ms: args.get_f64("ledger-ttl", d.ledger_ttl_ms),
        trace: trace.clone(),
        ..d
    };
    let sessions_target = args.get_usize("sessions", 0);
    let deploy_version = args.get("deploy-version").map(|s| s.to_string());
    let deploy_after = args.get_usize("deploy-after", 1);
    let version = args.get_or("version", "target_llama2t_base");
    let metrics_json = args.get("metrics-json").map(|s| s.to_string());
    let trace_path = args.get("trace").map(|s| s.to_string());

    let make_backend = make_backend_for(&backend_kind, seed, &version)?;

    let rt = tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()?;
    rt.block_on(async move {
        let handle = serve_cloud(&bind, vcfg, make_backend).await?;
        println!(
            "cloud verification server on {} ({backend_kind} backend)",
            handle.addr
        );
        // hot-swap poller runs in BOTH wait modes
        if let Some(v) = deploy_version {
            let vh = handle.verifier();
            tokio::spawn(async move { poll_and_deploy(&vh, &v, deploy_after).await });
        }
        if sessions_target == 0 {
            println!("serving until ctrl-c ...");
            tokio::signal::ctrl_c().await?;
        } else {
            println!("serving until {sessions_target} sessions complete ...");
            loop {
                tokio::time::sleep(std::time::Duration::from_millis(200)).await;
                if handle.stats().await?.sessions_completed >= sessions_target {
                    break;
                }
            }
        }
        let metrics = handle.shutdown().await?;
        println!("{}", metrics.render("serving totals"));
        if let Some(path) = metrics_json {
            std::fs::write(&path, metrics.to_json().to_string_pretty())?;
            println!("wrote metrics to {path}");
        }
        if let (Some(tr), Some(path)) = (&trace, &trace_path) {
            tr.write_jsonl(path)?;
            println!("wrote {} trace events to {path}", tr.len());
        }
        Ok(())
    })
}

/// `host:port` + i — fleet replicas bind consecutive ports.
fn bump_port(bind: &str, i: usize) -> Result<String> {
    let (host, port) = bind
        .rsplit_once(':')
        .ok_or_else(|| anyhow::anyhow!("--bind must be host:port, got '{bind}'"))?;
    let port: u16 = port
        .parse()
        .map_err(|_| anyhow::anyhow!("bad port in --bind '{bind}'"))?;
    let bumped = port as usize + i;
    if bumped > u16::MAX as usize {
        bail!("fleet replica {i} would exceed the port range (base {port})");
    }
    Ok(format!("{host}:{bumped}"))
}

/// One replica's backend factory (each replica owns its backend — the
/// whole point of per-replica versioned rollout).
fn make_backend_for(
    backend_kind: &str,
    seed: u64,
    version: &str,
) -> Result<Box<dyn FnOnce() -> Result<Box<dyn VerifyBackend>> + Send>> {
    match backend_kind {
        "synthetic" => Ok(Box::new(move || -> Result<Box<dyn VerifyBackend>> {
            Ok(Box::new(synthetic_fleet(seed)) as Box<dyn VerifyBackend>)
        })),
        "engine" => {
            let version = version.to_string();
            Ok(Box::new(move || -> Result<Box<dyn VerifyBackend>> {
                let reg = std::rc::Rc::new(crate::runtime::Registry::open_default()?);
                Ok(Box::new(EngineBackend::new(reg, &version, crate::workload::EOS)?)
                    as Box<dyn VerifyBackend>)
            }))
        }
        other => bail!("unknown --backend '{other}' (synthetic|engine)"),
    }
}

/// `serve-cloud --fleet N`: N TCP replicas on consecutive ports, one
/// verifier + backend each, sharing one handoff ledger through a
/// [`crate::serve::FleetRegistry`]. Optional orchestration while
/// serving: `--drain-after M` drains replica 0 to replica 1 once M
/// sessions completed fleet-wide (its sessions are redirected
/// mid-decode); `--deploy-version V --deploy-after M [--canary K]`
/// stages the rollout — V goes to the first K replicas at M completed
/// sessions and to the rest at 2M (the multi-node twin of the
/// single-node hot-swap).
fn serve_fleet_cmd(args: &Args, fleet: usize) -> Result<()> {
    use crate::serve::FleetRegistry;

    let bind = args.get_or("bind", "127.0.0.1:7411");
    let backend_kind = args.get_or("backend", "synthetic");
    let seed = args.get_u64("seed", 1);
    let d = VerifierConfig::default();
    let vcfg = VerifierConfig {
        window_ms: args.get_f64("window", 12.0),
        max_batch: args.get_usize("max-batch", 8),
        batch_mode: batch_mode_from(args)?,
        seed,
        resume_grace_ms: args.get_f64("resume-grace", 10_000.0),
        admission_queue: args.get_usize("admission-queue", 0),
        tier_reserve: args.get_usize("tier-reserve", d.tier_reserve),
        ledger_ttl_ms: args.get_f64("ledger-ttl", d.ledger_ttl_ms),
        ..d
    };
    let autoscale = args
        .flag("autoscale")
        .then(|| autoscale_config_from(args, fleet));
    let sessions_target = args.get_usize("sessions", 0);
    let deploy_version = args.get("deploy-version").map(|s| s.to_string());
    let deploy_after = args.get_usize("deploy-after", 1).max(1);
    let canary = args.get_usize("canary", 1).clamp(1, fleet);
    let drain_after = args.get_usize("drain-after", 0);
    let version = args.get_or("version", "target_llama2t_base");

    let rt = tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()?;
    rt.block_on(async move {
        let mut registry = FleetRegistry::new();
        registry.staleness_ms = args.get_f64("staleness", registry.staleness_ms).max(1.0);
        let mut handles = Vec::new();
        for i in 0..fleet {
            let addr = bump_port(&bind, i)?;
            let make = make_backend_for(&backend_kind, seed, &version)?;
            let handle =
                crate::serve::serve_cloud_with(&addr, vcfg.clone(), Some(registry.ledger()), make)
                    .await?;
            let actual = handle.addr.to_string();
            registry.register(&actual, handle.verifier());
            println!("replica {i} on {actual} ({backend_kind} backend)");
            handles.push(handle);
        }
        let mut addrs: Vec<String> = registry.replicas().iter().map(|r| r.addr.clone()).collect();
        println!(
            "fleet of {fleet}; edges: serve-edge --fleet-addrs {}",
            addrs.join(",")
        );
        if sessions_target == 0 {
            println!("serving until ctrl-c ...");
        } else {
            println!("serving until {sessions_target} sessions complete ...");
        }

        let ctrlc = tokio::signal::ctrl_c();
        tokio::pin!(ctrlc);
        let mut drained = false;
        let mut canary_done = false;
        let mut full_done = false;
        // live control loop: same policy as the loadgen sim twin, on
        // the wall clock. ScaleUp is actuated HERE (the controller does
        // not own the backend factory or the port scheme).
        let mut controller = autoscale.map(crate::autoscale::AutoscaleController::new);
        let mut spawned = fleet; // total replicas ever created (port bump)
        let t0 = std::time::Instant::now();
        let mut next_tick_ms = 0.0f64;
        loop {
            tokio::select! {
                _ = &mut ctrlc, if sessions_target == 0 => break,
                _ = tokio::time::sleep(std::time::Duration::from_millis(200)) => {}
            }
            if let Some(ctl) = controller.as_mut() {
                let now_ms = t0.elapsed().as_secs_f64() * 1000.0;
                if now_ms >= next_tick_ms {
                    next_tick_ms = now_ms + ctl.policy().config().tick_ms;
                    let actions = ctl.step(&mut registry, now_ms, None).await?;
                    for a in &actions {
                        println!("autoscale: {}", a.describe());
                        if let crate::autoscale::AutoscaleAction::ScaleUp { add } = *a {
                            for _ in 0..add {
                                let addr = bump_port(&bind, spawned)?;
                                spawned += 1;
                                let make = make_backend_for(&backend_kind, seed, &version)?;
                                let handle = crate::serve::serve_cloud_with(
                                    &addr,
                                    vcfg.clone(),
                                    Some(registry.ledger()),
                                    make,
                                )
                                .await?;
                                let actual = handle.addr.to_string();
                                registry.register(&actual, handle.verifier());
                                println!("autoscale: replica up on {actual}");
                                addrs.push(actual);
                                handles.push(handle);
                            }
                        }
                    }
                }
            }
            let mut completed = 0usize;
            for h in &handles {
                completed += h.stats().await?.sessions_completed;
            }
            if drain_after > 0 && !drained && completed >= drain_after {
                registry.drain(&addrs[0], &addrs[1])?;
                println!("draining {} -> {} ({completed} sessions done)", addrs[0], addrs[1]);
                drained = true;
            }
            if let Some(v) = &deploy_version {
                if !canary_done && completed >= deploy_after {
                    let subset: Vec<&str> =
                        addrs[..canary].iter().map(String::as_str).collect();
                    let seqs = registry.advance_version(&subset, v).await?;
                    println!("canary rollout of '{v}' to {canary} replica(s): seqs {seqs:?}");
                    canary_done = true;
                } else if canary_done && !full_done && canary < fleet
                    && completed >= deploy_after * 2
                {
                    let subset: Vec<&str> =
                        addrs[canary..].iter().map(String::as_str).collect();
                    let seqs = registry.advance_version(&subset, v).await?;
                    println!("full rollout of '{v}': seqs {seqs:?}");
                    full_done = true;
                }
            }
            if sessions_target > 0 && completed >= sessions_target {
                break;
            }
        }
        if let Some(ctl) = &controller {
            let p = ctl.policy();
            println!(
                "autoscale: {} ticks, {} actions, log digest {:016x}",
                ctl.ticks(),
                p.log().len(),
                p.log_digest()
            );
            if let Some(path) = args.get("action-log") {
                let lines: Vec<String> = p
                    .log()
                    .iter()
                    .map(|(t, a)| format!("{t} {}", a.describe()))
                    .collect();
                write_action_log(&path, &lines, p.log_digest())?;
                println!("wrote {} control actions to {path}", lines.len());
            }
        }
        // merged fleet snapshot while the replicas are still up — the
        // same pull the v6 `Stats` wire frame gives a remote edge
        let fs = registry.fleet_stats().await;
        println!(
            "fleet stats: {} replica(s), {} sessions, {} rounds, {} batches, {} tokens",
            fs.replicas, fs.sessions_completed, fs.rounds, fs.batches, fs.tokens_committed
        );
        print!("{}", fs.latency.render_lines("  "));
        let mut per_replica = Vec::new();
        for (i, h) in handles.into_iter().enumerate() {
            let metrics = h.shutdown().await?;
            println!("{}", metrics.render(&format!("replica {i} ({}) totals", addrs[i])));
            per_replica.push(metrics);
        }
        if let Some(path) = args.get("metrics-json") {
            use crate::util::json::Json;
            let j = Json::Arr(per_replica.iter().map(|m| m.to_json()).collect());
            std::fs::write(&path, j.to_string_pretty())?;
            println!("wrote per-replica metrics to {path}");
        }
        Ok(())
    })
}

/// Wait for `after` completed sessions, then hot-swap the target to
/// `version`. Exits quietly if the server shuts down first.
async fn poll_and_deploy(vh: &crate::serve::VerifierHandle, version: &str, after: usize) {
    loop {
        tokio::time::sleep(std::time::Duration::from_millis(200)).await;
        let Ok(stats) = vh.stats().await else {
            return; // server shut down before the trigger fired
        };
        if stats.sessions_completed >= after {
            match vh.deploy(version).await {
                Ok(seq) => println!("hot-swapped target to '{version}' (seq {seq})"),
                Err(e) => eprintln!("hot-swap of '{version}' failed: {e:#}"),
            }
            return;
        }
    }
}

/// The synthetic release train the `synthetic` backend can hot-swap
/// through: drift grows with each deployment, so the frozen edge draft's
/// acceptance visibly degrades — the paper's headline scenario without
/// artifacts.
fn synthetic_fleet(seed: u64) -> SyntheticTarget {
    SyntheticTarget::new(seed)
        .with_version("gsm8k_lora", 0.2)
        .with_version("nq_lora", 0.3)
        .with_version("code_full", 0.5)
}

fn make_edge_draft(kind: &str, seed: u64) -> Result<Box<dyn DraftSource + Send>> {
    match kind {
        "synthetic" => Ok(Box::new(SyntheticDraft::new(seed))),
        "pld" => Ok(Box::new(crate::coordinator::PromptLookup::pld(3))),
        other => bail!("unknown --draft '{other}' (synthetic|pld)"),
    }
}

/// A `Reconnect` factory dialing TCP, optionally wrapping every fresh
/// connection in a `FaultTransport` over the shared plan.
fn tcp_dial(addr: String, plan: Option<Arc<Mutex<FaultPlan>>>) -> Box<dyn Reconnect> {
    Box::new(move || -> BoxFuture<'static, Result<Box<dyn Transport>>> {
        let addr = addr.clone();
        let plan = plan.clone();
        Box::pin(async move {
            let t = TcpTransport::connect(&addr).await?;
            Ok(match plan {
                Some(p) => Box::new(crate::serve::FaultTransport::new(Box::new(t), p))
                    as Box<dyn Transport>,
                None => Box::new(t) as Box<dyn Transport>,
            })
        })
    })
}

fn fault_plan_for(fault_seed: u64, disconnects: usize, salt: u64) -> Arc<Mutex<FaultPlan>> {
    let seed = fault_seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    FaultPlan::shared(
        FaultConfig {
            seed,
            max_disconnects: disconnects,
            ..Default::default()
        },
        NetworkProfile::new(NetworkKind::FourG).channel(seed),
    )
}

/// `serve-edge`: run N concurrent edge sessions against a cloud server.
/// By default each session dials its own connection on its own OS
/// thread (the shape a fleet of independent edge devices has); with
/// `--mux` all N sessions are MULTIPLEXED over one connection. With
/// `--fault-seed` every connection is wrapped in a seeded
/// `FaultTransport` (forced disconnects + reconnect-and-resume), which
/// demos the resume path against a live server. `--pipeline-depth`
/// controls pipelined drafting (wire v3): 1 = sequential lock-step
/// (default), >= 2 keeps that many rounds in flight with
/// cancel-on-reject, 0 = the adaptive policy picks per round from the
/// measured channel.
fn serve_edge_cmd(args: &Args) -> Result<()> {
    // fleet mode: the list of replica addresses — the dial follows
    // Redirect handoffs, fails over past dead replicas, and re-roots a
    // session whose state was lost fleet-wide
    let fleet_addrs: Vec<String> = args
        .get("fleet-addrs")
        .map(|s| {
            s.split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect()
        })
        .unwrap_or_default();
    let addr = if fleet_addrs.is_empty() {
        args.get_or("addr", "127.0.0.1:7411")
    } else {
        fleet_addrs[0].clone()
    };
    let n = args.get_usize("sessions", 4);
    let seed = args.get_u64("seed", 1);
    let k = args.get_usize("k", 0);
    let mux = args.flag("mux");
    // per-tier uplink weights for muxed sessions, cycled across them
    // (e.g. --tier-weights 3,1 alternates premium/standard); empty =
    // every stream at the default tier (weight 1)
    let tier_weights: Vec<u32> = args
        .get("tier-weights")
        .map(|s| {
            s.split(',')
                .filter_map(|w| w.trim().parse().ok())
                .filter(|&w| w > 0)
                .collect()
        })
        .unwrap_or_default();
    let fault_seed = args.get_u64("fault-seed", 0); // 0 = no faults
    let fault_disconnects = args.get_usize("fault-disconnects", 1);
    if !fleet_addrs.is_empty() && fault_seed != 0 {
        bail!("--fleet-addrs and --fault-seed are mutually exclusive");
    }
    let draft_kind = args.get_or("draft", "synthetic");
    if !matches!(draft_kind.as_str(), "synthetic" | "pld") {
        bail!("unknown --draft '{draft_kind}' (synthetic|pld)");
    }
    let dataset = args.get_or("dataset", "mtbench");
    let mut gen = crate::workload::WorkloadGen::new(&dataset, seed)?;
    // one shared journal across all sessions (session ids are unique
    // per verifier, so rings never collide)
    let trace = args.get("trace").map(|_| Trace::wall());
    let ecfg = EdgeSessionConfig {
        max_new: args.get_usize("max-new", 32),
        fixed_k: if k == 0 { None } else { Some(k) },
        pipeline_depth: args.get_usize("pipeline-depth", 1),
        seed,
        trace: trace.clone(),
        // fleet edges survive replica death by re-opening from the
        // committed prefix on a survivor
        reroot_on_unknown_session: !fleet_addrs.is_empty(),
        ..Default::default()
    };
    let make_dial = {
        let fleet_addrs = fleet_addrs.clone();
        move |addr: String, plan: Option<Arc<Mutex<FaultPlan>>>| -> Box<dyn Reconnect> {
            if fleet_addrs.is_empty() {
                tcp_dial(addr, plan)
            } else {
                crate::serve::tcp_fleet_dial(fleet_addrs.clone())
            }
        }
    };

    let results: Vec<Result<EdgeReport>> = if mux {
        // one connection, N streams, session tasks on a shared runtime
        let rt = tokio::runtime::Builder::new_multi_thread()
            .worker_threads(2)
            .enable_all()
            .build()?;
        rt.block_on(async {
            let plan = (fault_seed != 0).then(|| fault_plan_for(fault_seed, fault_disconnects, 0));
            let mut dial = make_dial(addr.clone(), plan);
            let initial = dial.connect().await?;
            let mut emux = EdgeMux::connect(initial, Some(dial), &ecfg).await?;
            // a v2-negotiated connection cannot carry spec-tagged drafts
            // or Cancel frames: every muxed session runs sequentially
            let ecfg = if emux.wire_version() < 3 && ecfg.pipeline_depth != 1 {
                eprintln!(
                    "cloud negotiated wire v{}; pipelining disabled",
                    emux.wire_version()
                );
                EdgeSessionConfig {
                    pipeline_depth: 1,
                    ..ecfg.clone()
                }
            } else {
                ecfg.clone()
            };
            // wire v7 carries each stream's QoS tier in its Open, so
            // the cloud's `tier_reserve` admission headroom lines up
            // with the edge mux's weighted uplink; a pre-v7 cloud
            // rejects trailing Open bytes, so the tier is clamped off
            let wire_tier = emux.wire_version() >= 7;
            let mut tasks = Vec::new();
            for i in 0..n {
                let prompt = gen.next_request().prompt;
                let weight = if tier_weights.is_empty() {
                    1
                } else {
                    tier_weights[i % tier_weights.len()]
                };
                let mut stream = if tier_weights.is_empty() {
                    emux.open_stream()
                } else {
                    emux.open_stream_tier(weight)
                };
                let ecfg = EdgeSessionConfig {
                    tier: if wire_tier { weight } else { 1 },
                    ..ecfg.clone()
                };
                let dk = draft_kind.clone();
                tasks.push(tokio::spawn(async move {
                    let sid = stream.stream_id();
                    let mut draft = make_edge_draft(&dk, ecfg.seed)?;
                    run_session_on(&mut stream, sid, draft.as_mut(), &prompt, &ecfg).await
                }));
            }
            let mut out = Vec::new();
            for t in tasks {
                out.push(match t.await {
                    Ok(r) => r,
                    Err(e) => Err(anyhow::anyhow!("session task panicked: {e}")),
                });
            }
            // pull the cloud's histogram snapshot over the live control
            // stream (wire v6 Stats/StatsAck)
            match emux.fetch_stats().await {
                Ok(st) => {
                    println!(
                        "cloud stats (target seq {}): {} sessions, {} rounds, {} batches, {} tokens",
                        st.version, st.sessions_completed, st.rounds, st.batches,
                        st.tokens_committed
                    );
                    print!("{}", st.latency.render_lines("  "));
                }
                Err(e) => eprintln!("cloud stats unavailable: {e:#}"),
            }
            Ok::<_, anyhow::Error>(out)
        })?
    } else {
        // one connection per session, one OS thread each
        let mut threads = Vec::new();
        for i in 0..n {
            let prompt = gen.next_request().prompt;
            let addr = addr.clone();
            let ecfg = ecfg.clone();
            let dk = draft_kind.clone();
            let make_dial = make_dial.clone();
            let plan =
                (fault_seed != 0).then(|| fault_plan_for(fault_seed, fault_disconnects, 1 + i as u64));
            threads.push(std::thread::spawn(move || -> Result<EdgeReport> {
                let rt = tokio::runtime::Builder::new_current_thread()
                    .enable_all()
                    .build()?;
                rt.block_on(async move {
                    let mut draft = make_edge_draft(&dk, ecfg.seed)?;
                    let mut t =
                        ResumableTransport::connect(make_dial(addr, plan), &ecfg).await?;
                    run_edge_session(&mut t, draft.as_mut(), &prompt, &ecfg).await
                })
            }));
        }
        threads
            .into_iter()
            .map(|th| match th.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("edge session thread panicked")),
            })
            .collect()
    };

    let mode = if mux { "1 muxed conn" } else { "1 conn/session" };
    let mut table = crate::util::table::Table::new(
        &format!("edge sessions vs {addr} ({draft_kind} draft, {mode})"),
        &[
            "session", "tokens", "rounds", "accept", "mean K", "resumes", "piped", "cancelled",
            "busy", "redir", "rtt p50 ms", "wall ms",
        ],
    );
    let mut failures = 0usize;
    let mut edge_lat = LatencySummary::new();
    for res in results {
        match res {
            Ok(r) => {
                edge_lat.merge(&r.latency);
                table.row(vec![
                    r.session.to_string(),
                    r.new_tokens.to_string(),
                    r.rounds.to_string(),
                    format!("{:.2}", r.acceptance()),
                    format!("{:.1}", r.k_used.mean()),
                    r.resumes.to_string(),
                    r.rounds_pipelined.to_string(),
                    r.drafts_cancelled.to_string(),
                    r.busy_retries.to_string(),
                    format!("{}+{}", r.redirects, r.reroots),
                    format!("{:.2}", r.rtt_ms.p50()),
                    format!("{:.0}", r.wall_ms),
                ]);
            }
            Err(e) => {
                failures += 1;
                eprintln!("edge session failed: {e:#}");
            }
        }
    }
    println!("{}", table.render());
    print!("{}", edge_lat.render_lines("  "));
    if let (Some(tr), Some(path)) = (&trace, args.get("trace")) {
        tr.write_jsonl(path)?;
        println!("wrote {} trace events to {path}", tr.len());
    }
    if failures > 0 {
        bail!("{failures}/{n} edge sessions failed");
    }
    Ok(())
}

/// `loadgen <scenario>`: fleet-scale workload simulation on the
/// virtual clock (ROADMAP item 2; model and presets in
/// `docs/LOADGEN.md`). Scenario presets scale to `--sessions`; every
/// run finishes with the `ServingMetrics` conservation audit, and
/// `--selfcheck` re-runs the whole workload to assert the determinism
/// contract (byte-identical digest). `--trace` journals the first
/// [`crate::load::TRACE_SESSIONS`] sessions on the virtual clock.
fn loadgen_cmd(args: &Args) -> Result<()> {
    use crate::device::DeviceMix;
    use crate::load::{ChannelMix, Scenario};
    let Some(sc) = args.positional(1).and_then(Scenario::parse) else {
        bail!("usage: flexspec loadgen <steady|flash|diurnal|churn|hetero> [--sessions N] [--seed S]");
    };
    let sessions = args.get_usize("sessions", 10_000);
    let seed = args.get_u64("seed", 3);
    let mut cfg = sc.config(sessions, seed);
    cfg.replicas = args.get_usize("replicas", cfg.replicas).max(1);
    cfg.window_ms = args.get_f64("window", cfg.window_ms);
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch).max(1);
    cfg.batch_mode = batch_mode_from(args)?;
    cfg.fixed_k = args.get_usize("k", cfg.fixed_k).clamp(1, 64);
    cfg.admission_queue = args.get_usize("admission-queue", cfg.admission_queue);
    if let Some(m) = args.get("network-mix") {
        cfg.mix = ChannelMix::parse(&m)
            .ok_or_else(|| anyhow::anyhow!("bad --network-mix '{m}' (5g|4g|wifi or W5,W4,Ww)"))?;
    }
    if let Some(m) = args.get("device-mix") {
        cfg.device_mix =
            Some(DeviceMix::parse(&m).map_err(|e| anyhow::anyhow!("bad --device-mix: {e}"))?);
    }
    cfg.branching = args
        .get_usize("branching", cfg.branching)
        .clamp(1, crate::device::MAX_BRANCHING);
    if cfg.branching > 1 && cfg.device_mix.is_none() {
        bail!("--branching needs a device population (--device-mix or the hetero scenario)");
    }
    if args.flag("autoscale") {
        cfg.autoscale = Some(autoscale_config_from(args, cfg.replicas));
    } else if args.get("action-log").is_some() {
        bail!("--action-log needs --autoscale (there is no control loop without it)");
    }
    let trace = args.get("trace").map(|_| Trace::new(VirtualClock::shared()));
    println!(
        "loadgen/{}: {} sessions on {} replicas, mix {} (seed {seed})",
        sc.label(),
        cfg.sessions,
        cfg.replicas,
        cfg.mix.describe()
    );
    if let Some(dm) = &cfg.device_mix {
        println!(
            "  devices          {} (tree branching {})",
            dm.describe(),
            cfg.branching
        );
    }
    let t0 = std::time::Instant::now();
    let rep = crate::load::run_with(&cfg, trace.as_ref());
    let real_s = t0.elapsed().as_secs_f64();
    rep.metrics.check_invariants(0, 0);
    let violations = rep.metrics.invariant_violations(0, 0);
    if !violations.is_empty() {
        bail!("conservation audit failed:\n  {}", violations.join("\n  "));
    }
    println!("{}", rep.render());
    println!(
        "  real time        {:.2} s ({:.0} events/s)",
        real_s,
        rep.events as f64 / real_s.max(1e-9)
    );
    if args.flag("selfcheck") {
        let again = crate::load::run(&cfg);
        if again.digest() != rep.digest() {
            bail!(
                "determinism self-check FAILED: {:016x} != {:016x}",
                again.digest(),
                rep.digest()
            );
        }
        println!("  selfcheck        ok (second run digest {:016x})", again.digest());
    }
    if let (Some(a), Some(path)) = (&rep.autoscale, args.get("action-log")) {
        write_action_log(&path, &a.log_lines, a.log_digest)?;
        println!("wrote {} control actions to {path}", a.log_lines.len());
    }
    if let Some(path) = args.get("metrics-json") {
        std::fs::write(&path, rep.to_json().to_string_pretty())?;
        println!("wrote load report to {path}");
    }
    if let (Some(tr), Some(path)) = (&trace, args.get("trace")) {
        tr.write_jsonl(&path)?;
        println!("wrote {} trace events to {path}", tr.len());
    }
    Ok(())
}

fn trace_cmd(args: &Args) -> Result<()> {
    let Some(kind) = args.positional(1).and_then(NetworkKind::parse) else {
        bail!("usage: flexspec trace <5g|4g|wifi> <out.csv>");
    };
    let Some(out) = args.positional(2) else {
        bail!("usage: flexspec trace <5g|4g|wifi> <out.csv>");
    };
    let mut chan = NetworkProfile::new(kind).channel(args.get_u64("seed", 1));
    let trace = ChannelTrace::record(&mut chan, args.get_usize("samples", 1000), 100.0);
    trace.save(std::path::Path::new(out))?;
    println!("wrote {} samples to {out}", args.get_usize("samples", 1000));
    Ok(())
}
