//! CLI entry point: `flexspec <command> [options]`.
//!
//! Commands:
//!   list                         list experiments
//!   exp <id|all> [--requests N] [--seed S] [--report path.md]
//!   serve [--users N] [--network 5g|4g|wifi] [--window MS] ...
//!   info                         artifact + model zoo inventory
//!   trace <5g|4g|wifi> <out.csv> [--samples N]

use crate::channel::{ChannelTrace, NetworkKind, NetworkProfile};
use crate::coordinator::{serve, CloudEngine, ServeConfig};
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::experiments::Ctx;
use crate::util::cli::Args;
use anyhow::{bail, Result};
use std::path::PathBuf;

const VALUE_OPTS: &[&str] = &[
    "requests", "seed", "report", "users", "network", "window", "max-batch",
    "max-new", "dataset", "samples", "arrival-ms", "artifacts",
];

pub fn cli_main() -> Result<()> {
    let args = Args::from_env(VALUE_OPTS);
    if let Some(dir) = args.get("artifacts") {
        std::env::set_var("FLEXSPEC_ARTIFACTS", dir);
    }
    if args.flag("verbose") {
        crate::util::log::set_level(crate::util::log::Level::Debug);
    }
    match args.positional(0) {
        Some("list") => {
            println!("experiments:");
            for e in crate::experiments::all_experiments() {
                println!("  {:8} {}", e.id, e.title);
            }
            Ok(())
        }
        Some("info") => info(),
        Some("exp") => exp(&args),
        Some("serve") => serve_cmd(&args),
        Some("trace") => trace_cmd(&args),
        _ => {
            println!(
                "FlexSpec reproduction — usage:\n\
                 \x20 flexspec list\n\
                 \x20 flexspec info\n\
                 \x20 flexspec exp <id|all> [--requests N] [--seed S] [--report out.md]\n\
                 \x20 flexspec serve [--users N] [--network 5g|4g|wifi] [--window MS]\n\
                 \x20 flexspec trace <5g|4g|wifi> <out.csv> [--samples N]\n\
                 Run `make artifacts` first to build the AOT model zoo."
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    let reg = crate::runtime::Registry::open_default()?;
    let m = &reg.manifest;
    println!("artifacts: {}", m.root.display());
    println!("block={} k_max={} prefill_chunk={}", m.block, m.k_max, m.prefill_chunk);
    println!("\narchitectures:");
    for (name, a) in &m.archs {
        println!(
            "  {:24} vocab={:5} d={} L={} heads={} ff={} experts={} lora_r={} params={}",
            name, a.vocab, a.d_model, a.n_layers, a.n_heads, a.d_ff, a.n_experts, a.lora_rank,
            a.n_params()
        );
    }
    println!("\nweight bundles:");
    for (name, w) in &m.weights {
        println!("  {:36} kind={:13} arch={}", name, w.kind, w.arch);
    }
    if !m.calibration.is_empty() {
        println!("\nbuild-time acceptance calibration:");
        for (k, v) in &m.calibration {
            println!("  {k:32} {v:.3}");
        }
    }
    Ok(())
}

fn exp(args: &Args) -> Result<()> {
    let ids: Vec<String> = if args.positional.len() > 1 {
        args.positional[1..].to_vec()
    } else {
        vec!["all".to_string()]
    };
    let requests = args.get_usize("requests", 6);
    let seed = args.get_u64("seed", 7);
    let mut ctx = Ctx::open(requests, seed)?;
    ctx.verbose = args.flag("verbose");
    let entries = crate::report::run_experiments(&ctx, &ids)?;
    if let Some(path) = args.get("report") {
        let header = format!(
            "# FlexSpec reproduction — experiment results\n\n\
             requests/cell = {requests}, seed = {seed}. Regenerate with\n\
             `cargo run --release -- exp all --requests {requests} --seed {seed} --report <path>`.\n"
        );
        crate::report::write_markdown(&entries, &PathBuf::from(path), &header)?;
    }
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    let reg = crate::runtime::Registry::open_default()?;
    let network = NetworkKind::parse(&args.get_or("network", "4g"))
        .ok_or_else(|| anyhow::anyhow!("bad --network"))?;
    let users = args.get_usize("users", 8);
    let dataset = args.get_or("dataset", "mtbench");
    let mut gen = crate::workload::WorkloadGen::new(&dataset, args.get_u64("seed", 1))?;
    let prompts: Vec<Vec<i32>> = gen.take(users).into_iter().map(|r| r.prompt).collect();

    let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", crate::workload::EOS)?;
    let draft = reg.model("draft_flex_llama2t")?;
    let cfg = ServeConfig {
        users,
        window_ms: args.get_f64("window", 12.0),
        max_batch: args.get_usize("max-batch", 8),
        max_new: args.get_usize("max-new", 32),
        arrival_mean_ms: args.get_f64("arrival-ms", 300.0),
        seed: args.get_u64("seed", 1),
        ..Default::default()
    };
    let net = NetworkProfile::new(network);
    let rep = serve(&mut cloud, draft, &prompts, &JETSON_ORIN, &A800_70B, &net, &cfg)?;
    println!("served {} sessions on {} ({} dataset)", rep.completed, network.label(), dataset);
    println!("  tokens           {}", rep.tokens);
    println!("  wall time        {:.1} ms (virtual)", rep.wall_ms);
    println!("  throughput       {:.1} tok/s", rep.throughput_tok_s());
    println!("  mean batch size  {:.2} ({} batches)", rep.mean_batch, rep.batches);
    println!("  T_base amortized {:.0} ms saved", rep.t_base_saved_ms);
    println!("  request latency  p50 {:.0} ms  p95 {:.0} ms", rep.request_latency.p50(), rep.request_latency.p95());
    println!("  per-token        p50 {:.0} ms  p95 {:.0} ms", rep.per_token_latency.p50(), rep.per_token_latency.p95());
    println!("  acceptance       {:.2}", rep.acceptance.mean());
    Ok(())
}

fn trace_cmd(args: &Args) -> Result<()> {
    let Some(kind) = args.positional(1).and_then(NetworkKind::parse) else {
        bail!("usage: flexspec trace <5g|4g|wifi> <out.csv>");
    };
    let Some(out) = args.positional(2) else {
        bail!("usage: flexspec trace <5g|4g|wifi> <out.csv>");
    };
    let mut chan = NetworkProfile::new(kind).channel(args.get_u64("seed", 1));
    let trace = ChannelTrace::record(&mut chan, args.get_usize("samples", 1000), 100.0);
    trace.save(std::path::Path::new(out))?;
    println!("wrote {} samples to {out}", args.get_usize("samples", 1000));
    Ok(())
}
