//! Cloud-side parallel verification (Algorithm 2, step 2).
//!
//! The cloud holds the evolving target version (base weights + the
//! currently deployed LoRA adapter — hot-swappable through the registry)
//! and one KV-cache session per user. Each verify round forwards
//! [pending committed tokens ++ draft block] in ONE target pass, runs the
//! fused Pallas verification kernel (greedy) or the Leviathan acceptance
//! test (stochastic), and rolls the KV back to the accepted prefix by
//! position-pointer rewind (§IV-C).

use crate::protocol::VerifyMode;
use crate::runtime::model::{BatchFwdItem, KvState};
use crate::runtime::registry::TargetVersion;
use crate::runtime::sampling::{self, VerifyOutcome};
use crate::runtime::{Registry, VerifyRuntime};
use crate::util::rng::SplitMix64;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::rc::Rc;

pub struct CloudEngine {
    pub version: TargetVersion,
    verify_rt: Rc<VerifyRuntime>,
    sessions: HashMap<u32, KvState>,
    pub eos: i32,
    /// Rounds verified (metrics).
    pub rounds: u64,
    /// KV rollbacks performed (metrics; == rounds with tau < K).
    pub rollbacks: u64,
}

pub struct CloudVerdict {
    pub outcome: VerifyOutcome,
    /// Tokens newly committed to the session KV this round (pending
    /// prefix + accepted draft tokens). The correction token is NOT in
    /// the KV yet — it is next round's pending token.
    pub committed_tokens: usize,
    pub eos: bool,
}

/// One member of a stacked greedy verification call — the coordinator-
/// layer mirror of `serve::backend::BatchVerifyReq`, kept separate so
/// the runtime layer never depends on serve types.
#[derive(Debug, Clone, Copy)]
pub struct GreedyBatchReq<'a> {
    pub id: u32,
    /// Full committed sequence (prompt + generated).
    pub committed: &'a [i32],
    pub draft: &'a [i32],
}

impl CloudEngine {
    pub fn new(reg: &Registry, version_name: &str, eos: i32) -> Result<CloudEngine> {
        let version = reg.target_version(version_name)?;
        let verify_rt = reg.verify(version.runtime.arch.vocab)?;
        Ok(CloudEngine {
            version,
            verify_rt,
            sessions: HashMap::new(),
            eos,
            rounds: 0,
            rollbacks: 0,
        })
    }

    /// Hot-swap the deployed target version (the paper's cloud-side model
    /// evolution; the edge never hears about it).
    pub fn deploy(&mut self, reg: &Registry, version_name: &str) -> Result<()> {
        let v = reg.target_version(version_name)?;
        if v.runtime.arch.name != self.version.runtime.arch.name {
            bail!(
                "cannot hot-swap across architectures ({} -> {})",
                self.version.runtime.arch.name,
                v.runtime.arch.name
            );
        }
        self.version = v;
        // KV caches remain valid only for sessions that already ran on the
        // old version in this reproduction we keep them (the backbone is
        // frozen; adapters only perturb) — matches the paper's stateless-
        // with-respect-to-draft, stateful-KV design.
        Ok(())
    }

    /// Start a session: ingest prompt[..len-1]; prompt's last token is
    /// the first pending token of round 1.
    pub fn start_session(&mut self, id: u32, prompt: &[i32]) -> Result<()> {
        if prompt.len() < 2 {
            bail!("prompt must have at least 2 tokens (BOS + 1)");
        }
        let mut kv = self.version.runtime.new_kv()?;
        self.version
            .runtime
            .prefill(Some(&self.version.lora), &prompt[..prompt.len() - 1], &mut kv)?;
        self.sessions.insert(id, kv);
        Ok(())
    }

    pub fn end_session(&mut self, id: u32) {
        self.sessions.remove(&id);
    }

    pub fn session_kv_pos(&self, id: u32) -> Option<usize> {
        self.sessions.get(&id).map(|kv| kv.pos)
    }

    pub fn remaining_capacity(&self, id: u32) -> usize {
        self.sessions
            .get(&id)
            .map(|kv| kv.remaining())
            .unwrap_or(0)
    }

    /// Verify one draft block for session `id`.
    ///
    /// `committed` is the full committed sequence (prompt + generated);
    /// `draft`/`draft_probs` the proposal. Greedy mode uses the fused
    /// Pallas kernel; stochastic mode the Leviathan test.
    #[allow(clippy::too_many_arguments)]
    pub fn verify(
        &mut self,
        id: u32,
        committed: &[i32],
        draft: &[i32],
        draft_probs: &[Vec<f32>],
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<CloudVerdict> {
        let kv = self
            .sessions
            .get_mut(&id)
            .ok_or_else(|| anyhow::anyhow!("no session {id}"))?;
        let pending = &committed[kv.pos..];
        if pending.is_empty() {
            bail!("session {id}: nothing pending (protocol violation)");
        }
        let k = draft.len();
        let block_len = pending.len() + k;
        let rt = &self.version.runtime;
        if block_len > rt.block {
            bail!("block {} exceeds {} (pending {} + k {})", block_len, rt.block, pending.len(), k);
        }

        let mut block_tokens = Vec::with_capacity(block_len);
        block_tokens.extend_from_slice(pending);
        block_tokens.extend_from_slice(draft);

        // Forward WITHOUT committing yet; commit after verification.
        let pos_before = kv.pos;
        let out = rt.forward_block(Some(&self.version.lora), &block_tokens, kv, 0)?;

        // Rows: row (pending.len()-1 + j) is the distribution after
        // consuming draft[0..j], j = 0..=k.
        let first = pending.len() - 1;
        let vocab = rt.arch.vocab;
        let rows = &out.logits[first * vocab..(first + k + 1) * vocab];

        let outcome = match mode {
            VerifyMode::Greedy => {
                // fused Pallas kernel over a fixed 9-row block
                let mut padded = vec![0f32; self.verify_rt.block * vocab];
                padded[..rows.len()].copy_from_slice(rows);
                let mut dtoks = vec![0i32; self.verify_rt.block - 1];
                dtoks[..k].copy_from_slice(draft);
                let (tau, corr, _greedy) = self.verify_rt.verify(&padded, &dtoks, k)?;
                VerifyOutcome {
                    tau,
                    correction: corr,
                }
            }
            VerifyMode::Stochastic => {
                // model-free drafts (PLD/Lookahead) propose deterministic
                // continuations: their draft distribution is a point mass
                // on the proposed token (p_d = 1), which is exactly what
                // the Leviathan acceptance test needs.
                let point_mass;
                let probs: &[Vec<f32>] = if draft_probs.len() >= k {
                    draft_probs
                } else {
                    point_mass = draft
                        .iter()
                        .map(|&t| {
                            let mut p = vec![0f32; vocab];
                            p[t as usize] = 1.0;
                            p
                        })
                        .collect::<Vec<_>>();
                    &point_mass
                };
                sampling::stochastic_verify(
                    rows,
                    vocab,
                    probs,
                    draft,
                    k,
                    temperature,
                    top_p,
                    rng,
                )
            }
        };

        // Commit pending + accepted prefix; rewind the rest (KV rollback).
        let committed_tokens = pending.len() + outcome.tau;
        kv.pos = pos_before + committed_tokens;
        self.rounds += 1;
        if outcome.tau < k {
            self.rollbacks += 1;
        }

        let eos = outcome.correction == self.eos
            || draft[..outcome.tau].iter().any(|&t| t == self.eos);
        Ok(CloudVerdict {
            outcome,
            committed_tokens,
            eos,
        })
    }

    /// Verify one planner bucket of greedy drafts in a SINGLE stacked
    /// runtime call: plan every member's block, execute all forwards
    /// through `ModelRuntime::forward_block_batched` (one engine
    /// dispatch), then run the fused verify kernel + KV commit/rollback
    /// per member, in request order. Byte-identical to per-member
    /// [`CloudEngine::verify`] calls — stacking amortizes the fixed
    /// per-call cost, it never changes a verdict.
    ///
    /// Session ids must be distinct within one call. On error the whole
    /// batch is poisoned (members' KV sessions may already be consumed);
    /// the serving layer treats a failed batch as fatal to the verifier
    /// thread, exactly like a failed single verify.
    pub fn verify_batch_greedy(
        &mut self,
        reqs: &[GreedyBatchReq<'_>],
    ) -> Result<Vec<CloudVerdict>> {
        // ---- plan: pull each member's KV out of the session map so the
        // stacked forward can hold every mutable KV at once ------------
        let rt = &self.version.runtime;
        let mut kvs: Vec<KvState> = Vec::with_capacity(reqs.len());
        let mut blocks: Vec<Vec<i32>> = Vec::with_capacity(reqs.len());
        for r in reqs {
            let kv = self.sessions.remove(&r.id).ok_or_else(|| {
                anyhow::anyhow!("no session {} (or duplicate id in batch)", r.id)
            })?;
            let pending = &r.committed[kv.pos.min(r.committed.len())..];
            if pending.is_empty() {
                bail!("session {}: nothing pending (protocol violation)", r.id);
            }
            let block_len = pending.len() + r.draft.len();
            if block_len > rt.block {
                bail!(
                    "block {} exceeds {} (pending {} + k {})",
                    block_len,
                    rt.block,
                    pending.len(),
                    r.draft.len()
                );
            }
            let mut toks = Vec::with_capacity(block_len);
            toks.extend_from_slice(pending);
            toks.extend_from_slice(r.draft);
            blocks.push(toks);
            kvs.push(kv);
        }

        // ---- execute: one stacked forward for the whole bucket -------
        let mut items: Vec<BatchFwdItem> = blocks
            .iter()
            .zip(kvs.iter_mut())
            .map(|(toks, kv)| BatchFwdItem {
                tokens: toks.as_slice(),
                kv,
            })
            .collect();
        let outs = rt.forward_block_batched(Some(&self.version.lora), &mut items)?;
        drop(items);

        // ---- apply: fused verify kernel + commit per member ----------
        let vocab = rt.arch.vocab;
        let mut verdicts = Vec::with_capacity(reqs.len());
        for ((r, mut kv), out) in reqs.iter().zip(kvs).zip(outs) {
            let pending_len = r.committed.len() - kv.pos;
            let k = r.draft.len();
            let first = pending_len - 1;
            let rows = &out.logits[first * vocab..(first + k + 1) * vocab];
            let mut padded = vec![0f32; self.verify_rt.block * vocab];
            padded[..rows.len()].copy_from_slice(rows);
            let mut dtoks = vec![0i32; self.verify_rt.block - 1];
            dtoks[..k].copy_from_slice(r.draft);
            let (tau, corr, _greedy) = self.verify_rt.verify(&padded, &dtoks, k)?;
            let outcome = VerifyOutcome {
                tau,
                correction: corr,
            };
            // commit pending + accepted prefix; rewind the rest (the
            // position-pointer rewind IS the KV rollback)
            let committed_tokens = pending_len + outcome.tau;
            kv.pos += committed_tokens;
            self.rounds += 1;
            if outcome.tau < k {
                self.rollbacks += 1;
            }
            let eos = outcome.correction == self.eos
                || r.draft[..outcome.tau].iter().any(|&t| t == self.eos);
            self.sessions.insert(r.id, kv);
            verdicts.push(CloudVerdict {
                outcome,
                committed_tokens,
                eos,
            });
        }
        Ok(verdicts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Engine, Manifest};

    fn registry() -> Option<Registry> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root).ok()?;
        if !m.weights.contains_key("target_llama2t_base") {
            return None;
        }
        Some(Registry::open(
            Rc::new(Engine::cpu().ok()?),
            Rc::new(m),
        ))
    }

    #[test]
    fn greedy_self_drafts_always_accept() {
        // Draft tokens computed from the TARGET's own greedy trajectory
        // must be fully accepted — the lossless-ness sanity check.
        let Some(reg) = registry() else { return };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let prompt: Vec<i32> = vec![1, 70, 77, 85, 90];
        cloud.start_session(1, &prompt).unwrap();
        let mut rng = SplitMix64::new(5);

        // obtain target greedy continuation via k=0 rounds
        let mut committed = prompt.clone();
        let mut greedy = Vec::new();
        for _ in 0..4 {
            let v = cloud
                .verify(1, &committed, &[], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
                .unwrap();
            greedy.push(v.outcome.correction);
            committed.push(v.outcome.correction);
        }
        cloud.end_session(1);

        // fresh session: propose those 4 tokens at once
        cloud.start_session(2, &prompt).unwrap();
        let v = cloud
            .verify(2, &prompt, &greedy, &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
            .unwrap();
        assert_eq!(v.outcome.tau, 4, "self-draft must be fully accepted");
        assert_eq!(v.committed_tokens, 1 + 4);
    }

    #[test]
    fn wrong_draft_rejected_with_correct_correction() {
        let Some(reg) = registry() else { return };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let prompt: Vec<i32> = vec![1, 70, 77, 85, 90];
        cloud.start_session(1, &prompt).unwrap();
        let mut rng = SplitMix64::new(5);
        // true greedy next token:
        let v0 = cloud
            .verify(1, &prompt, &[], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
            .unwrap();
        let truth = v0.outcome.correction;
        cloud.end_session(1);

        cloud.start_session(2, &prompt).unwrap();
        let wrong = if truth == 100 { 101 } else { 100 };
        let v = cloud
            .verify(2, &prompt, &[wrong, 50], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
            .unwrap();
        assert_eq!(v.outcome.tau, 0);
        assert_eq!(v.outcome.correction, truth);
        assert_eq!(cloud.rollbacks, 1);
    }

    #[test]
    fn rollback_preserves_trajectory() {
        // A rejected round must not corrupt the session: the next round's
        // greedy output equals a clean session's output.
        let Some(reg) = registry() else { return };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let prompt: Vec<i32> = vec![1, 64, 67, 86];
        let mut rng = SplitMix64::new(6);

        // clean trajectory, 3 tokens
        cloud.start_session(1, &prompt).unwrap();
        let mut clean = prompt.clone();
        for _ in 0..3 {
            let v = cloud
                .verify(1, &clean, &[], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
                .unwrap();
            clean.push(v.outcome.correction);
        }

        // dirty: first round proposes garbage (rejected), then continues
        cloud.start_session(2, &prompt).unwrap();
        let mut dirty = prompt.clone();
        let v = cloud
            .verify(2, &dirty, &[3, 3, 3, 3], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
            .unwrap();
        assert_eq!(v.outcome.tau, 0);
        dirty.push(v.outcome.correction);
        for _ in 0..2 {
            let v = cloud
                .verify(2, &dirty, &[], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
                .unwrap();
            dirty.push(v.outcome.correction);
        }
        assert_eq!(clean, dirty);
    }

    #[test]
    fn lora_hot_swap_changes_behaviour() {
        let Some(reg) = registry() else { return };
        if !reg.manifest.weights.contains_key("lora_llama2t_gsm8k") {
            return;
        }
        let mut rng = SplitMix64::new(7);
        let prompt: Vec<i32> = vec![1, 70, 77, 85, 90, 71, 80];
        let mut run = |cloud: &mut CloudEngine| {
            cloud.start_session(9, &prompt).unwrap();
            let mut c = prompt.clone();
            for _ in 0..8 {
                let v = cloud
                    .verify(9, &c, &[], &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
                    .unwrap();
                c.push(v.outcome.correction);
            }
            cloud.end_session(9);
            c
        };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let a = run(&mut cloud);
        cloud.deploy(&reg, "lora_llama2t_gsm8k").unwrap();
        let b = run(&mut cloud);
        assert_ne!(a, b, "gsm8k adapter should change the math trajectory");
    }

    #[test]
    fn block_overflow_rejected() {
        let Some(reg) = registry() else { return };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let prompt: Vec<i32> = vec![1, 70, 77];
        cloud.start_session(1, &prompt).unwrap();
        let mut rng = SplitMix64::new(5);
        let draft = vec![5i32; 9]; // pending 1 + 9 > block 9
        assert!(cloud
            .verify(1, &prompt, &draft, &[], VerifyMode::Greedy, 0.0, 1.0, &mut rng)
            .is_err());
    }
}
