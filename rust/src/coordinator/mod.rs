//! L3 coordinator — the paper's system contribution (DESIGN.md S7-S10):
//! edge drafting engine, cloud verification engine with KV sessions and
//! LoRA hot-swap, the channel-aware adaptive speculation policy, the full
//! Algorithm-2 pipeline under a virtual clock, the multi-user batching
//! scheduler, and the update-storm sync model.

pub mod cloud;
pub mod edge;
pub mod pipeline;
pub mod policy;
pub mod scheduler;
pub mod sync;

pub use cloud::CloudEngine;
pub use edge::{DraftSource, ModelDraft, NoDraft, PromptLookup, Proposal, TreeProposal};
pub use pipeline::{Pipeline, RequestResult, RoundLog, StridePolicy};
pub use policy::{AcceptanceModel, AdaptivePolicy, LatencyModel};
pub use scheduler::{serve, serve_with, FleetSimConfig, ServeConfig, ServeReport};
