//! The full edge-cloud decode loop (paper Algorithm 2 + Fig. 3) under a
//! virtual clock: real models decide WHAT happens (every tau comes from
//! actual draft/target execution through PJRT); the latency model of
//! eqs. (7)-(10) decides WHEN (DESIGN.md substitution log).

use super::cloud::CloudEngine;
use super::edge::{DraftSource, Proposal};
use super::policy::{AdaptivePolicy, LatencyModel};
use crate::channel::Channel;
use crate::devices::{CloudProfile, EdgeDevice};
use crate::energy::{EnergyBreakdown, EnergyMeter};
use crate::protocol::{self, DraftMsg, VerifyMode, VerifyMsg, WireFormat};
use crate::util::rng::SplitMix64;
use anyhow::Result;

/// Stride selection strategy (FlexSpec adaptive vs baselines).
#[derive(Debug, Clone)]
pub enum StridePolicy {
    /// FlexSpec: channel-aware K* search (eq. 11).
    Adaptive(AdaptivePolicy),
    /// Fixed stride (Std-SD, EAGLE-2, Medusa, and the Fig. 5 ablation).
    Fixed(usize),
    /// DSSD: network-class heuristic + acceptance EMA, but blind to the
    /// instantaneous channel state.
    Dssd { base_k: usize, policy: AdaptivePolicy },
    /// Cloud-only: never draft.
    None,
}

impl StridePolicy {
    pub fn choose(&mut self, lat: &LatencyModel) -> usize {
        match self {
            StridePolicy::Adaptive(p) => p.select_k(lat),
            StridePolicy::Fixed(k) => *k,
            StridePolicy::Dssd { base_k, policy } => {
                // scale the static class stride by the acceptance EMA only
                let g = policy.gamma.get();
                ((*base_k as f64 * (0.5 + g)).round() as usize).clamp(1, policy.k_max)
            }
            StridePolicy::None => 0,
        }
    }

    pub fn observe(&mut self, tau: usize, k: usize) {
        match self {
            StridePolicy::Adaptive(p) | StridePolicy::Dssd { policy: p, .. } => p.observe(tau, k),
            _ => {}
        }
    }

    pub fn label(&self) -> String {
        match self {
            StridePolicy::Adaptive(_) => "adaptive".into(),
            StridePolicy::Fixed(k) => format!("fixed(K={k})"),
            StridePolicy::Dssd { base_k, .. } => format!("dssd(base={base_k})"),
            StridePolicy::None => "none".into(),
        }
    }
}

/// Per-round telemetry (drives every figure).
#[derive(Debug, Clone)]
pub struct RoundLog {
    pub k: usize,
    pub tau: usize,
    pub committed: usize,
    pub t_step_ms: f64,
    pub t_edge_ms: f64,
    pub t_up_ms: f64,
    pub t_cloud_ms: f64,
    pub t_down_ms: f64,
    pub bytes_up: usize,
    pub bytes_down: usize,
    pub fading: bool,
}

/// End-to-end result of one request.
#[derive(Debug, Clone, Default)]
pub struct RequestResult {
    pub method: String,
    pub prompt_tokens: usize,
    pub new_tokens: usize,
    pub rounds: usize,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub bytes_up: usize,
    pub bytes_down: usize,
    pub drafted: usize,
    pub accepted: usize,
    /// Pipelined mode: rounds whose draft + uplink were hidden behind
    /// the previous round's verify + downlink (speculation held).
    pub rounds_pipelined: usize,
    /// Pipelined mode: speculative drafts retracted (prefix broke).
    pub drafts_cancelled: usize,
    /// Pipelined mode: draft tokens of retracted rounds.
    pub draft_tokens_wasted: usize,
    pub energy: EnergyBreakdown,
    pub rounds_log: Vec<RoundLog>,
    pub output: Vec<i32>,
}

impl RequestResult {
    /// The paper's headline metric: decode latency per generated token.
    pub fn ms_per_token(&self) -> f64 {
        self.decode_ms / self.new_tokens.max(1) as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    pub fn etgr_tokens_per_s(&self) -> f64 {
        self.new_tokens as f64 / (self.decode_ms / 1e3).max(1e-9)
    }

    pub fn energy_per_token_j(&self) -> f64 {
        self.energy.total_j() / self.new_tokens.max(1) as f64
    }
}

/// Everything an experiment configures about one decode pipeline.
pub struct Pipeline<'a> {
    pub draft: Box<dyn DraftSource + 'a>,
    pub cloud: &'a mut CloudEngine,
    pub channel: &'a mut dyn Channel,
    pub policy: StridePolicy,
    pub device: &'a EdgeDevice,
    pub cloud_profile: &'a CloudProfile,
    pub mode: VerifyMode,
    pub wire: WireFormat,
    pub temperature: f32,
    pub top_p: f32,
    pub method: String,
    /// Pipelined drafting (`serve::pipeline` twin under the virtual
    /// clock): 1 = sequential; >= 2 overlaps the next round's draft +
    /// uplink with the current round's verify + downlink,
    /// cancel-on-reject. One speculative round in flight (depth-2
    /// model); requires a pure draft source, otherwise sequential.
    pub pipeline_depth: usize,
    session_counter: u32,
}

impl<'a> Pipeline<'a> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        draft: Box<dyn DraftSource + 'a>,
        cloud: &'a mut CloudEngine,
        channel: &'a mut dyn Channel,
        policy: StridePolicy,
        device: &'a EdgeDevice,
        cloud_profile: &'a CloudProfile,
        mode: VerifyMode,
        temperature: f32,
        top_p: f32,
        method: impl Into<String>,
    ) -> Pipeline<'a> {
        Pipeline {
            draft,
            cloud,
            channel,
            policy,
            device,
            cloud_profile,
            mode,
            wire: WireFormat::Compact,
            temperature,
            top_p,
            method: method.into(),
            pipeline_depth: 1,
            session_counter: 0,
        }
    }

    pub fn with_wire(mut self, wire: WireFormat) -> Self {
        self.wire = wire;
        self
    }

    /// Enable pipelined drafting (see the `pipeline_depth` field docs).
    pub fn with_pipeline_depth(mut self, depth: usize) -> Self {
        self.pipeline_depth = depth.max(1);
        self
    }

    /// Run one request to EOS or `max_new` tokens. Virtual-time account:
    ///   round = t_edge + t_up + t_cloud + t_down   (eq. 7)
    pub fn run_request(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        seed: u64,
    ) -> Result<RequestResult> {
        self.session_counter += 1;
        let sid = self.session_counter;
        let mut rng = SplitMix64::new(seed ^ 0x5E55_1011);
        let mut now_ms = 0.0f64;
        let mut meter = EnergyMeter::new(self.device);
        let mut res = RequestResult {
            method: self.method.clone(),
            prompt_tokens: prompt.len(),
            ..Default::default()
        };

        // --- session setup: prompt uplink + prefills -------------------
        self.draft.reset()?;
        self.draft.on_prompt(prompt.len());
        self.cloud.start_session(sid, prompt)?;
        let st0 = self.channel.sample(now_ms);
        let prompt_bytes = protocol::prompt_air_bytes(prompt.len());
        let up0 = st0.prop_ms + st0.up_ms(prompt_bytes);
        res.bytes_up += prompt_bytes;
        meter.radio_burst(st0.up_ms(prompt_bytes), now_ms + up0);
        // edge draft prefill runs concurrently with cloud prefill; the
        // pipeline stalls on the slower of the two.
        let edge_prefill = if self.draft.is_neural() {
            prompt.len() as f64 * self.device.prefill_ms_per_token
        } else {
            0.0
        };
        meter.compute(edge_prefill);
        let cloud_prefill = self.cloud_profile.prefill_ms(prompt.len());
        now_ms += up0 + edge_prefill.max(cloud_prefill);
        res.prefill_ms = now_ms;

        let mut committed: Vec<i32> = prompt.to_vec();
        let eos = self.cloud.eos;
        let mut round_idx = 0u32;

        // --- pipelined drafting state (depth-2 virtual model) ----------
        // The speculative next-round draft rides the previous round's
        // verify + downlink window; if its optimistic prefix holds, the
        // round's draft + uplink cost collapses to the overflow beyond
        // that window (see serve::pipeline for the full state machine).
        struct SpecNext {
            prop: Proposal,
            /// Bonus token the speculation bets the current round commits.
            link_bonus: i32,
            /// Edge draft + uplink ms spent concurrently with the verify.
            cost_ms: f64,
            bytes_up: usize,
        }
        let pipelining = self.pipeline_depth > 1 && self.draft.is_pure();
        let mut spec: Option<SpecNext> = None;
        // previous round's (full_accept, correction) — the validity link
        let mut prev_outcome: Option<(bool, i32)> = None;
        // previous round's t_cloud + t_down: the hideable window
        let mut shadow_ms = 0.0f64;

        // --- decode loop (Algorithm 2) ---------------------------------
        while res.new_tokens < max_new {
            // capacity guard: pending(1) + k + safety must fit both caches
            let cap = self
                .cloud
                .remaining_capacity(sid)
                .min(255)
                .saturating_sub(2);
            if cap == 0 {
                break;
            }

            // Step 1a: measure channel, choose K*.
            let chan = self.channel.sample(now_ms);
            let lat = LatencyModel::build(&chan, self.device, self.cloud_profile, self.wire);

            // Step 1b+1c: the round's draft + uplink — taken from the
            // surviving speculation (already drafted AND uplinked during
            // the previous round's verify) or produced fresh.
            let mut from_spec: Option<(f64, usize)> = None; // (cost, bytes)
            // a cancelled speculation still occupies the single-threaded
            // edge for whatever part of its burst outlasted the verify +
            // downlink shadow — the redraft cannot start before that
            let mut stall_ms = 0.0f64;
            let prop: Proposal = match spec.take() {
                Some(sp)
                    if prev_outcome
                        .is_some_and(|(full, corr)| full && corr == sp.link_bonus)
                        && sp.prop.tokens.len() <= cap =>
                {
                    res.rounds_pipelined += 1;
                    from_spec = Some((sp.cost_ms, sp.bytes_up));
                    sp.prop
                }
                other => {
                    if let Some(sp) = other {
                        // cancel-on-reject: the uplink bytes are spent
                        // either way, the tokens are waste
                        res.drafts_cancelled += 1;
                        res.draft_tokens_wasted += sp.prop.tokens.len();
                        res.bytes_up += sp.bytes_up;
                        stall_ms = (sp.cost_ms - shadow_ms).max(0.0);
                    }
                    let mut k = self.policy.choose(&lat);
                    k = k.min(8).min(cap);
                    self.draft
                        .propose(&committed, k, self.temperature, self.top_p, &mut rng)?
                }
            };
            let k_actual = prop.tokens.len();
            let (t_edge, t_up, bytes_up) = match from_spec {
                Some((cost, bytes)) => {
                    // hidden behind the previous round's shadow; only
                    // the overflow (if any) stalls the pipeline. Energy
                    // was metered at launch.
                    (0.0, (cost - shadow_ms).max(0.0), bytes)
                }
                None => {
                    let t_edge = if self.draft.is_neural() {
                        self.device.round_overhead_ms
                            + prop.edge_tokens as f64 * self.device.draft_ms_per_token
                    } else {
                        self.device.round_overhead_ms * 0.25 // lookup cost
                    };
                    meter.compute(t_edge);
                    let msg = DraftMsg {
                        session: sid,
                        round: round_idx,
                        tokens: prop.tokens.clone(),
                        chosen_probs: prop.chosen_probs.clone(),
                        mode: self.mode,
                        wire: self.wire,
                        basis_len: 0,
                        spec: vec![],
                        tree: vec![],
                    };
                    let bytes_up = msg.air_bytes();
                    let tx_ms = chan.up_ms(bytes_up);
                    let t_up = chan.prop_ms + tx_ms;
                    meter.radio_burst(tx_ms, now_ms + t_edge + t_up);
                    (t_edge + stall_ms, t_up, bytes_up)
                }
            };

            // Step 1d (pipelined): launch the NEXT round's speculative
            // draft from the optimistic prefix; it rides this round's
            // verify + downlink window.
            if pipelining && !prop.tokens.is_empty() {
                // budget gate: a round that only exists if the
                // speculation FAILS is never worth drafting
                let optimistic_new = res.new_tokens + k_actual + 1;
                if optimistic_new < max_new {
                    let mut ctx = committed.clone();
                    ctx.extend_from_slice(&prop.tokens);
                    let bonus = self
                        .draft
                        .propose(&ctx, 1, self.temperature, self.top_p, &mut rng)?
                        .tokens
                        .first()
                        .copied();
                    if let Some(b) = bonus {
                        ctx.push(b);
                        let k2 = self.policy.choose(&lat).min(8);
                        let sprop = self.draft.propose(
                            &ctx,
                            k2,
                            self.temperature,
                            self.top_p,
                            &mut rng,
                        )?;
                        if !sprop.tokens.is_empty() {
                            let smsg = DraftMsg {
                                session: sid,
                                round: round_idx + 1,
                                tokens: sprop.tokens.clone(),
                                chosen_probs: sprop.chosen_probs.clone(),
                                mode: self.mode,
                                wire: self.wire,
                                basis_len: committed.len() as u64,
                                spec: prop.tokens.iter().copied().chain([b]).collect(),
                                tree: vec![],
                            };
                            let sbytes = smsg.air_bytes();
                            // pure sources are model-free: lookup cost
                            let s_edge = self.device.round_overhead_ms * 0.25;
                            let s_tx = chan.up_ms(sbytes);
                            meter.compute(s_edge);
                            meter.radio_burst(s_tx, now_ms + t_edge + t_up + s_edge + s_tx);
                            spec = Some(SpecNext {
                                prop: sprop,
                                link_bonus: b,
                                cost_ms: s_edge + chan.prop_ms + s_tx,
                                bytes_up: sbytes,
                            });
                        }
                    }
                }
            }

            // Step 2: cloud verification (real model + fused kernel).
            let verdict = self.cloud.verify(
                sid,
                &committed,
                &prop.tokens,
                &prop.prob_rows,
                self.mode,
                self.temperature,
                self.top_p,
                &mut rng,
            )?;
            let t_cloud = self.cloud_profile.verify_ms(k_actual + 1);
            meter.idle(t_cloud + chan.prop_ms);

            // Step 3: downlink + state update.
            let vmsg = VerifyMsg {
                session: sid,
                round: round_idx,
                tau: verdict.outcome.tau as u8,
                correction: verdict.outcome.correction,
                eos: verdict.eos,
                leaf: None,
            };
            let bytes_down = vmsg.air_bytes();
            let rx_ms = chan.down_ms(bytes_down);
            let t_down = chan.prop_ms + rx_ms;
            let t_step = t_edge + t_up + t_cloud + t_down;
            meter.radio_burst(rx_ms, now_ms + t_step);
            now_ms += t_step;

            let tau = verdict.outcome.tau;
            for &t in &prop.tokens[..tau] {
                committed.push(t);
            }
            committed.push(verdict.outcome.correction);
            let gained = tau + 1;
            res.new_tokens += gained;
            res.drafted += k_actual;
            res.accepted += tau;
            res.bytes_up += bytes_up;
            res.bytes_down += bytes_down;
            if k_actual > 0 {
                self.policy.observe(tau, k_actual);
            }
            res.rounds += 1;
            res.rounds_log.push(RoundLog {
                k: k_actual,
                tau,
                committed: gained,
                t_step_ms: t_step,
                t_edge_ms: t_edge,
                t_up_ms: t_up,
                t_cloud_ms: t_cloud,
                t_down_ms: t_down,
                bytes_up,
                bytes_down,
                fading: chan.fading,
            });
            round_idx += 1;
            // pipelined bookkeeping: the window the next round's spec
            // rode, and the outcome its validity hinges on
            shadow_ms = t_cloud + t_down;
            prev_outcome = Some((tau == k_actual && k_actual > 0, verdict.outcome.correction));

            if verdict.eos {
                break;
            }
        }
        // speculation still in flight when the request ended is waste
        if let Some(sp) = spec {
            res.drafts_cancelled += 1;
            res.draft_tokens_wasted += sp.prop.tokens.len();
            res.bytes_up += sp.bytes_up;
        }

        res.decode_ms = now_ms - res.prefill_ms;
        res.energy = meter.finish(now_ms);
        res.output = committed[prompt.len()..].to_vec();
        // the last speculative round can overshoot the token budget;
        // truncate to max_new like any serving API would
        res.output.truncate(max_new);
        // truncate output at EOS if present
        if let Some(p) = res.output.iter().position(|&t| t == eos) {
            res.output.truncate(p + 1);
        }
        self.cloud.end_session(sid);
        Ok(res)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::{ChannelState, ConstChannel};
    use crate::coordinator::edge::{ModelDraft, NoDraft, PromptLookup};
    use crate::devices::{A800_70B, JETSON_ORIN};
    use crate::runtime::{Engine, Manifest, Registry};
    use std::rc::Rc;

    fn registry() -> Option<Registry> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root).ok()?;
        if !m.weights.contains_key("draft_flex_llama2t") {
            return None;
        }
        Some(Registry::open(Rc::new(Engine::cpu().ok()?), Rc::new(m)))
    }

    fn const_chan() -> ConstChannel {
        ConstChannel(ChannelState {
            up_bps: 50e6,
            down_bps: 100e6,
            prop_ms: 20.0,
            fading: false,
            loss_rate: 0.002,
        })
    }

    #[test]
    fn cloud_only_generates_one_token_per_round() {
        let Some(reg) = registry() else { return };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let mut chan = const_chan();
        let mut p = Pipeline::new(
            Box::new(NoDraft),
            &mut cloud,
            &mut chan,
            StridePolicy::None,
            &JETSON_ORIN,
            &A800_70B,
            VerifyMode::Greedy,
            0.0,
            1.0,
            "cloud-only",
        );
        let prompt = vec![1i32, 70, 77, 85];
        let r = p.run_request(&prompt, 10, 42).unwrap();
        assert_eq!(r.rounds, r.new_tokens);
        assert_eq!(r.drafted, 0);
        assert!(r.decode_ms > 0.0 && r.prefill_ms > 0.0);
        // per-token latency ≈ t_fixed of the model (А800 + 2×prop 20ms)
        assert!(r.ms_per_token() > A800_70B.t_base_ms);
    }

    #[test]
    fn flexspec_beats_cloud_only_in_virtual_time() {
        let Some(reg) = registry() else { return };
        let prompt = vec![1i32, 70, 77, 85, 90, 71];

        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let mut chan = const_chan();
        let mut co = Pipeline::new(
            Box::new(NoDraft),
            &mut cloud,
            &mut chan,
            StridePolicy::None,
            &JETSON_ORIN,
            &A800_70B,
            VerifyMode::Greedy,
            0.0,
            1.0,
            "cloud-only",
        );
        let base = co.run_request(&prompt, 24, 1).unwrap();

        let draft_rt = reg.model("draft_flex_llama2t").unwrap();
        let mut cloud2 = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let mut chan2 = const_chan();
        let mut fs = Pipeline::new(
            Box::new(ModelDraft::new(draft_rt).unwrap()),
            &mut cloud2,
            &mut chan2,
            StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.1)),
            &JETSON_ORIN,
            &A800_70B,
            VerifyMode::Greedy,
            0.0,
            1.0,
            "flexspec",
        );
        let flex = fs.run_request(&prompt, 24, 1).unwrap();

        assert!(flex.acceptance_rate() > 0.5, "accept {}", flex.acceptance_rate());
        assert!(
            flex.ms_per_token() < base.ms_per_token() * 0.8,
            "flex {} vs cloud-only {}",
            flex.ms_per_token(),
            base.ms_per_token()
        );
        // consistency: every round commits tau+1 tokens
        for r in &flex.rounds_log {
            assert_eq!(r.committed, r.tau + 1);
            assert!(r.tau <= r.k);
        }
    }

    #[test]
    fn greedy_pipeline_output_matches_cloud_only_output() {
        // Losslessness: greedy speculative decoding must produce the SAME
        // token sequence as plain target decoding.
        let Some(reg) = registry() else { return };
        let prompt = vec![1i32, 64, 67, 86, 93];

        let run = |draft: Box<dyn DraftSource>, policy: StridePolicy, name: &str| {
            let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
            let mut chan = const_chan();
            let mut p = Pipeline::new(
                draft,
                &mut cloud,
                &mut chan,
                policy,
                &JETSON_ORIN,
                &A800_70B,
                VerifyMode::Greedy,
                0.0,
                1.0,
                name,
            );
            p.run_request(&prompt, 20, 9).unwrap().output
        };

        let a = run(Box::new(NoDraft), StridePolicy::None, "cloud-only");
        let draft_rt = reg.model("draft_flex_llama2t").unwrap();
        let b = run(
            Box::new(ModelDraft::new(draft_rt).unwrap()),
            StridePolicy::Fixed(5),
            "flexspec",
        );
        assert_eq!(a, b, "speculative decoding must be lossless");
    }

    #[test]
    fn pipelined_request_is_lossless_and_never_slower() {
        // Pipelined single-request decoding (depth 2, pure PLD draft)
        // must emit the exact sequential output; valid speculation can
        // only SHRINK virtual decode time (broken prefixes cost tokens
        // and bytes, never latency).
        let Some(reg) = registry() else { return };
        let prompt = vec![1i32, 64, 67, 86, 93, 64, 67];
        let run = |depth: usize| {
            let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
            let mut chan = const_chan();
            let mut p = Pipeline::new(
                Box::new(PromptLookup::pld(2)),
                &mut cloud,
                &mut chan,
                StridePolicy::Fixed(4),
                &JETSON_ORIN,
                &A800_70B,
                VerifyMode::Greedy,
                0.0,
                1.0,
                "pld",
            )
            .with_pipeline_depth(depth);
            p.run_request(&prompt, 16, 3).unwrap()
        };
        let seq = run(1);
        let pipe = run(2);
        assert_eq!(seq.output, pipe.output, "pipelining must be lossless");
        assert_eq!(seq.new_tokens, pipe.new_tokens);
        assert!(pipe.decode_ms <= seq.decode_ms + 1e-9);
        assert_eq!(seq.rounds_pipelined, 0);
        assert_eq!(seq.drafts_cancelled, 0);
    }

    #[test]
    fn energy_batching_beats_streaming() {
        // Fig. 6 mechanism end-to-end: FlexSpec's per-round bursts cost
        // less radio energy per token than Cloud-Only streaming.
        let Some(reg) = registry() else { return };
        let prompt = vec![1i32, 70, 77, 85, 90, 71];

        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let mut chan = const_chan();
        let mut co = Pipeline::new(
            Box::new(NoDraft),
            &mut cloud,
            &mut chan,
            StridePolicy::None,
            &crate::devices::SNAPDRAGON_8G3,
            &A800_70B,
            VerifyMode::Greedy,
            0.0,
            1.0,
            "cloud-only",
        );
        let base = co.run_request(&prompt, 24, 3).unwrap();

        let draft_rt = reg.model("draft_flex_llama2t").unwrap();
        let mut cloud2 = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let mut chan2 = const_chan();
        let mut fs = Pipeline::new(
            Box::new(ModelDraft::new(draft_rt).unwrap()),
            &mut cloud2,
            &mut chan2,
            StridePolicy::Fixed(6),
            &crate::devices::SNAPDRAGON_8G3,
            &A800_70B,
            VerifyMode::Greedy,
            0.0,
            1.0,
            "flexspec",
        );
        let flex = fs.run_request(&prompt, 24, 3).unwrap();
        let e_base = base.energy.radio_tail_j / base.new_tokens as f64;
        let e_flex = flex.energy.radio_tail_j / flex.new_tokens as f64;
        assert!(e_flex < e_base, "tail energy/token {e_flex} !< {e_base}");
    }
}
