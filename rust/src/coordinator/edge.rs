//! Edge-side drafting (Algorithm 2, step 1).
//!
//! `DraftSource` abstracts *how* draft tokens are proposed so the same
//! pipeline runs FlexSpec and every baseline:
//!   * `ModelDraft`    — a real draft LM through PJRT (FlexSpec's aligned
//!     draft, Std-SD's generic draft, EAGLE-2/Medusa's synced drafts);
//!   * `PromptLookup`  — PLD: n-gram string matching over the context;
//!   * `LookaheadDraft`— Lookahead-style n-gram pool over prompt AND
//!     generated text (Jacobi-refined pool approximated by the pool hits);
//!   * `NoDraft`       — Cloud-Only (K = 0 every round).
//!
//! The draft KV cache is speculative: after each round it is rolled back
//! to the committed prefix (position-pointer rewind) and the next round
//! re-ingests the accepted tokens — same rollback semantics the cloud
//! uses (§IV-C).

use crate::runtime::model::KvState;
use crate::runtime::sampling::{sample_top_p, softmax_temp};
use crate::runtime::ModelRuntime;
use crate::util::rng::SplitMix64;
use anyhow::Result;
use std::collections::HashMap;
use std::rc::Rc;

/// One round's draft proposal.
#[derive(Debug, Clone, Default)]
pub struct Proposal {
    pub tokens: Vec<i32>,
    /// p_d(token) for each proposal — goes on the wire (stochastic mode).
    pub chosen_probs: Vec<f32>,
    /// Full draft distribution per proposal — used by the cloud verifier
    /// (reconstructed from the wire sketch in a real deployment; see
    /// protocol docs).
    pub prob_rows: Vec<Vec<f32>>,
    /// Number of *model forward* tokens the edge executed this round
    /// (pending re-ingest + draft steps) — drives the virtual edge time.
    pub edge_tokens: usize,
}

/// One round's TREE draft proposal (wire v8 tree speculation): a main
/// chain plus single-token alternate leaves, laid out exactly as
/// `DraftMsg::{tokens, tree}` — chain nodes first, alternates appended,
/// `parents[i]` naming node `i`'s parent (0 = committed prefix,
/// `j > 0` = child of `tokens[j-1]`). An empty `parents` array IS the
/// linear chain.
#[derive(Debug, Clone, Default)]
pub struct TreeProposal {
    /// All tree node tokens, chain first.
    pub tokens: Vec<i32>,
    /// Tree topology (`DraftMsg::tree` convention); empty = linear.
    pub parents: Vec<u8>,
    /// Number of *model forward* tokens the edge executed this round —
    /// every alternate leaf costs one extra draft step.
    pub edge_tokens: usize,
}

impl TreeProposal {
    /// Number of tree nodes drafted (chain + alternates).
    pub fn n_nodes(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_linear(&self) -> bool {
        self.parents.is_empty()
    }
}

pub trait DraftSource {
    /// Propose up to `k` tokens extending `committed`.
    fn propose(
        &mut self,
        committed: &[i32],
        k: usize,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<Proposal>;

    /// Propose a token TREE extending `committed`: a main chain of up to
    /// `k` tokens plus alternate leaves, at most `branching` children
    /// per node (wire v8). The default delegates to the linear
    /// [`propose`](DraftSource::propose) and returns it as a chain, so
    /// every source keeps working; sources that can hedge against
    /// target drift override it. Contract: `branching <= 1` MUST return
    /// a linear tree (empty `parents`) whose chain is byte-identical to
    /// `propose` — the degenerate-case equality the device-matrix suite
    /// pins.
    fn propose_tree(
        &mut self,
        committed: &[i32],
        k: usize,
        _branching: usize,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<TreeProposal> {
        let p = self.propose(committed, k, temperature, top_p, rng)?;
        Ok(TreeProposal {
            edge_tokens: p.edge_tokens,
            tokens: p.tokens,
            parents: Vec::new(),
        })
    }

    /// Start a new request (context reset).
    fn reset(&mut self) -> Result<()>;

    /// Notify the source of the new request's prompt length (PLD needs
    /// the prompt/generation boundary). Default: ignore.
    fn on_prompt(&mut self, _prompt_len: usize) {}

    fn name(&self) -> String;

    /// Edge memory footprint in bytes (RQ5 table). 0 for model-free.
    fn edge_bytes(&self) -> usize {
        0
    }

    /// True if this source runs a neural draft on the edge accelerator
    /// (drives the compute-energy/time model).
    fn is_neural(&self) -> bool {
        false
    }

    /// True when `propose` is a PURE function of `(committed, config)` —
    /// no internal KV state, no rng consumption. Only pure sources may
    /// drive pipelined drafting (`serve::pipeline`): a basis-valid
    /// speculative draft must be byte-identical to the draft a
    /// sequential edge would produce from the confirmed prefix, and the
    /// extra bonus-prediction lookahead calls must not perturb later
    /// proposals. Stateful sources (KV-cached neural drafts) default to
    /// `false` and fall back to sequential decoding.
    fn is_pure(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------
// Neural draft through the PJRT runtime
// ---------------------------------------------------------------------

pub struct ModelDraft {
    pub runtime: Rc<ModelRuntime>,
    kv: KvState,
    label: String,
}

impl ModelDraft {
    pub fn new(runtime: Rc<ModelRuntime>) -> Result<ModelDraft> {
        let kv = runtime.new_kv()?;
        let label = format!("draft:{}", runtime.weights.info.name);
        Ok(ModelDraft { runtime, kv, label })
    }

    /// Ingest committed tokens the draft KV has not seen. Returns the
    /// logits row after the final committed token.
    fn ingest(&mut self, committed: &[i32]) -> Result<(Vec<f32>, usize)> {
        // Defensive rewind: if the cache claims more positions than the
        // committed sequence has (caller rolled history back, or a bench
        // reused a draft across contexts), the tail is stale speculation —
        // rewind so it gets overwritten. Callers must still guarantee the
        // prefix below kv.pos matches `committed` (reset() otherwise).
        if self.kv.pos >= committed.len() {
            self.kv.pos = committed.len() - 1;
        }
        let mut fed = 0usize;
        let mut last_row: Option<Vec<f32>> = None;
        // long catch-ups (fresh session prompt) go through the prefill exe
        while committed.len() - self.kv.pos >= self.runtime.prefill_chunk {
            let start = self.kv.pos;
            let chunk = &committed[start..start + self.runtime.prefill_chunk];
            last_row = Some(self.runtime.prefill(None, chunk, &mut self.kv)?);
            fed += chunk.len();
        }
        if self.kv.pos < committed.len() {
            let start = self.kv.pos;
            let pending = &committed[start..];
            // pending can exceed one block only right after prefill chunking
            for chunk in pending.chunks(self.runtime.block) {
                let out = self
                    .runtime
                    .forward_block(None, chunk, &mut self.kv, chunk.len())?;
                last_row = Some(out.row(chunk.len() - 1).to_vec());
                fed += chunk.len();
            }
        }
        Ok((last_row.expect("ingest fed at least one token"), fed))
    }
}

impl DraftSource for ModelDraft {
    fn propose(
        &mut self,
        committed: &[i32],
        k: usize,
        temperature: f32,
        top_p: f32,
        rng: &mut SplitMix64,
    ) -> Result<Proposal> {
        let commit_len = committed.len();
        let (mut row, mut fed) = self.ingest(committed)?;
        let mut prop = Proposal::default();
        for _ in 0..k {
            if self.kv.remaining() == 0 {
                break; // draft context exhausted; propose fewer
            }
            let probs = softmax_temp(&row, temperature.max(1e-3));
            let tok = sample_top_p(&row, temperature, top_p, rng) as i32;
            prop.chosen_probs.push(probs[tok as usize]);
            prop.prob_rows.push(probs);
            prop.tokens.push(tok);
            if prop.tokens.len() == k {
                break; // last proposal needs no further forward
            }
            let out = self.runtime.forward_block(None, &[tok], &mut self.kv, 1)?;
            row = out.row(0).to_vec();
            fed += 1;
        }
        // speculative rollback: KV keeps only the committed prefix
        self.kv.pos = commit_len.min(self.kv.pos);
        prop.edge_tokens = fed; // ingest feeds + (k-1) draft-step feeds
        Ok(prop)
    }

    fn reset(&mut self) -> Result<()> {
        self.kv = self.runtime.new_kv()?;
        Ok(())
    }

    fn name(&self) -> String {
        self.label.clone()
    }

    fn edge_bytes(&self) -> usize {
        self.runtime.weights.byte_size
    }

    fn is_neural(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// Prompt-lookup decoding (PLD): n-gram match over the prompt window
// ---------------------------------------------------------------------

pub struct PromptLookup {
    /// n-gram key length.
    pub n: usize,
    /// Match over the full context (Lookahead-style) or prompt only (PLD).
    pub include_generated: bool,
    prompt_len: usize,
}

impl PromptLookup {
    pub fn pld(n: usize) -> PromptLookup {
        PromptLookup {
            n,
            include_generated: false,
            prompt_len: usize::MAX,
        }
    }

    /// Lookahead-style: the Jacobi iteration's n-gram pool is approximated
    /// by context-wide n-gram reuse (the pool's hit source).
    pub fn lookahead(n: usize) -> PromptLookup {
        PromptLookup {
            n,
            include_generated: true,
            prompt_len: usize::MAX,
        }
    }

    pub fn set_prompt_len(&mut self, len: usize) {
        self.prompt_len = len;
    }
}

impl DraftSource for PromptLookup {
    fn on_prompt(&mut self, prompt_len: usize) {
        if !self.include_generated {
            self.prompt_len = prompt_len;
        }
    }

    fn propose(
        &mut self,
        committed: &[i32],
        k: usize,
        _temperature: f32,
        _top_p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<Proposal> {
        let hay_end = if self.include_generated {
            committed.len().saturating_sub(1)
        } else {
            self.prompt_len.min(committed.len().saturating_sub(1))
        };
        let mut prop = Proposal::default();
        if committed.len() < self.n || hay_end < self.n {
            return Ok(prop);
        }
        let key = &committed[committed.len() - self.n..];
        // most recent match wins
        let mut found: Option<usize> = None;
        for start in (0..hay_end.saturating_sub(self.n)).rev() {
            if &committed[start..start + self.n] == key {
                found = Some(start + self.n);
                break;
            }
        }
        if let Some(cont) = found {
            for j in 0..k {
                let idx = cont + j;
                if idx >= hay_end {
                    break;
                }
                prop.tokens.push(committed[idx]);
                prop.chosen_probs.push(1.0);
            }
        }
        Ok(prop)
    }

    fn reset(&mut self) -> Result<()> {
        Ok(())
    }

    fn name(&self) -> String {
        if self.include_generated {
            format!("lookahead(n={})", self.n)
        } else {
            format!("pld(n={})", self.n)
        }
    }

    fn is_pure(&self) -> bool {
        true // n-gram lookup over the context: no state, no sampling
    }
}

// ---------------------------------------------------------------------
// Cloud-only: no drafting at all
// ---------------------------------------------------------------------

pub struct NoDraft;

impl DraftSource for NoDraft {
    fn propose(
        &mut self,
        _committed: &[i32],
        _k: usize,
        _t: f32,
        _p: f32,
        _rng: &mut SplitMix64,
    ) -> Result<Proposal> {
        Ok(Proposal::default())
    }

    fn reset(&mut self) -> Result<()> {
        Ok(())
    }

    fn name(&self) -> String {
        "cloud-only".into()
    }

    fn is_pure(&self) -> bool {
        true // proposes nothing, trivially pure
    }
}

/// Count the frequency of each next token after an n-gram (diagnostics
/// for the workload generator + PLD tuning).
pub fn ngram_stats(tokens: &[i32], n: usize) -> HashMap<Vec<i32>, usize> {
    let mut out = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *out.entry(w.to_vec()).or_insert(0) += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_lookup_finds_repeats() {
        let mut pld = PromptLookup::pld(2);
        pld.set_prompt_len(8);
        // context: [5,6,7,8,  5,6, ...] key (5,6) matches at start -> 7,8
        let committed = vec![5, 6, 7, 8, 1, 2, 3, 4, 5, 6];
        let mut rng = SplitMix64::new(1);
        let p = pld.propose(&committed, 4, 0.0, 1.0, &mut rng).unwrap();
        assert_eq!(p.tokens, vec![7, 8, 1, 2]);
        assert!(p.chosen_probs.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn prompt_lookup_misses_cleanly() {
        let mut pld = PromptLookup::pld(3);
        pld.set_prompt_len(6);
        let committed = vec![1, 2, 3, 4, 5, 6, 9, 9, 9];
        let mut rng = SplitMix64::new(1);
        let p = pld.propose(&committed, 4, 0.0, 1.0, &mut rng).unwrap();
        assert!(p.tokens.is_empty());
    }

    #[test]
    fn lookahead_uses_generated_tail_pld_does_not() {
        // repeat appears only in the generated region (after prompt_len=4)
        let committed = vec![9, 9, 9, 9, 1, 2, 3, 7, 1, 2];
        let mut rng = SplitMix64::new(1);
        let mut la = PromptLookup::lookahead(2);
        let p = la.propose(&committed, 2, 0.0, 1.0, &mut rng).unwrap();
        assert_eq!(p.tokens, vec![3, 7]);
        let mut pld = PromptLookup::pld(2);
        pld.set_prompt_len(4);
        let p2 = pld.propose(&committed, 2, 0.0, 1.0, &mut rng).unwrap();
        assert!(p2.tokens.is_empty());
    }

    #[test]
    fn default_propose_tree_is_the_linear_chain() {
        // every source gets tree drafting for free as the degenerate
        // linear case, byte-identical to `propose`
        let mut pld = PromptLookup::pld(2);
        pld.set_prompt_len(8);
        let committed = vec![5, 6, 7, 8, 1, 2, 3, 4, 5, 6];
        let mut rng = SplitMix64::new(1);
        let lin = pld.propose(&committed, 4, 0.0, 1.0, &mut rng).unwrap();
        for branching in [1usize, 4] {
            let t = pld
                .propose_tree(&committed, 4, branching, 0.0, 1.0, &mut rng)
                .unwrap();
            assert!(t.is_linear());
            assert_eq!(t.tokens, lin.tokens);
            assert_eq!(t.n_nodes(), lin.tokens.len());
            assert_eq!(t.edge_tokens, lin.edge_tokens);
        }
    }

    #[test]
    fn no_draft_proposes_nothing() {
        let mut nd = NoDraft;
        let mut rng = SplitMix64::new(1);
        let p = nd.propose(&[1, 2, 3], 8, 1.0, 0.9, &mut rng).unwrap();
        assert!(p.tokens.is_empty() && !nd.is_neural());
    }

    #[test]
    fn ngram_stats_counts() {
        let s = ngram_stats(&[1, 2, 1, 2, 1], 2);
        assert_eq!(s[&vec![1, 2]], 2);
        assert_eq!(s[&vec![2, 1]], 2);
    }

    // ModelDraft correctness is covered by the artifact-gated pipeline
    // tests in pipeline.rs (requires `make artifacts`).
}
