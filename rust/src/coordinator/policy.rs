//! Channel-aware adaptive speculation (paper §IV-B, eqs. (7)–(11)) —
//! FlexSpec's second contribution.
//!
//! Each round the edge builds the refined latency model
//!
//!   T_step(K, R_n) = T_fixed + K * T_marginal(n)
//!   T_fixed        = T_prop + T_base + T_down + O_header/R_n + beta
//!   T_marginal(n)  = alpha_edge + b/R_n + delta_cloud
//!
//! and selects K* = argmax_{K in [1, K_max]} E[tau|K] / T_step(K, R_n).
//! E[tau|K] uses either the linear EMA approximation 1 + gamma*K of
//! Algorithm 2 or the geometric model sum_{i<=K} gamma^i (both from the
//! paper's §IV-B.2 discussion); the +1 counts the correction/bonus token
//! every round commits.

use crate::channel::ChannelState;
use crate::device::{ComputeTier, SpecPlan};
use crate::devices::{CloudProfile, EdgeDevice};
use crate::protocol::{self, WireFormat};
use crate::util::stats::Ema;

/// How E[tau | K] is approximated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AcceptanceModel {
    /// E[tau|K] ≈ gamma_hat * K (paper's EMA linearization).
    Linear,
    /// E[tau|K] = sum_{i=1..K} gamma^i (i.i.d. geometric acceptance).
    Geometric,
}

/// The per-round latency decomposition (returned for metrics/reports).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyModel {
    pub t_fixed_ms: f64,
    pub t_marginal_ms: f64,
}

impl LatencyModel {
    /// Eq. (10).
    pub fn build(
        chan: &ChannelState,
        device: &EdgeDevice,
        cloud: &CloudProfile,
        wire: WireFormat,
    ) -> LatencyModel {
        let header_ms = (protocol::O_HEADER_BYTES as f64 * 8.0) / chan.up_bps * 1e3;
        let downlink_ms = (protocol::O_HEADER_BYTES as f64 * 8.0) / chan.down_bps * 1e3 + 16.0 / chan.down_bps * 1e3;
        let t_fixed = 2.0 * chan.prop_ms          // T_prop up + T_down prop
            + cloud.t_base_ms                     // T_base
            + header_ms + downlink_ms             // O_header / R_n
            + device.round_overhead_ms; // beta
        let token_bytes = protocol::bits_per_token(wire) / 8.0;
        let arq_ms = token_bytes / crate::channel::MTU_BYTES * chan.loss_rate * crate::channel::RTO_MS;
        let t_marginal = device.draft_ms_per_token            // alpha_edge
            + protocol::bits_per_token(wire) / chan.up_bps * 1e3 // b / R_n
            + arq_ms                                          // expected ARQ cost
            + cloud.delta_per_token_ms; // delta_cloud
        LatencyModel {
            t_fixed_ms: t_fixed,
            t_marginal_ms: t_marginal,
        }
    }

    /// T_step(K) of eq. (10).
    pub fn step_ms(&self, k: usize) -> f64 {
        self.t_fixed_ms + k as f64 * self.t_marginal_ms
    }
}

pub fn expected_tau(model: AcceptanceModel, gamma: f64, k: usize) -> f64 {
    match model {
        AcceptanceModel::Linear => gamma * k as f64,
        AcceptanceModel::Geometric => {
            let mut s = 0.0;
            let mut g = gamma;
            for _ in 0..k {
                s += g;
                g *= gamma;
            }
            s
        }
    }
}

/// ETGR(K) of eq. (2)/(11): committed tokens per ms. Every round commits
/// the accepted prefix plus one correction/bonus token.
pub fn etgr(model: AcceptanceModel, gamma: f64, lat: &LatencyModel, k: usize) -> f64 {
    (1.0 + expected_tau(model, gamma, k)) / lat.step_ms(k)
}

/// The channel-aware policy state: gamma-hat EMA + configuration.
#[derive(Debug, Clone)]
pub struct AdaptivePolicy {
    pub gamma: Ema,
    pub k_max: usize,
    pub model: AcceptanceModel,
}

impl AdaptivePolicy {
    /// Algorithm 2 initialization: gamma_hat <- 0.8, decay mu.
    ///
    /// Default acceptance model is GEOMETRIC: the paper's linear EMA
    /// approximation `E[tau|K] ≈ gamma*K` makes ETGR monotone in K
    /// (d/dK has constant sign), so K* degenerates to 1 or K_max and the
    /// policy cannot express the Fig.-2 interior optima. The geometric
    /// model `sum gamma^i` (also §IV-B.2) saturates and yields genuine
    /// channel-dependent K*. The linear variant is kept for the ablation.
    pub fn new(k_max: usize, mu: f64) -> AdaptivePolicy {
        AdaptivePolicy {
            gamma: Ema::new(0.8, mu),
            k_max,
            model: AcceptanceModel::Geometric,
        }
    }

    pub fn with_model(mut self, model: AcceptanceModel) -> AdaptivePolicy {
        self.model = model;
        self
    }

    /// Eq. (11): search K in [1, K_max] maximizing ETGR. K_max is tiny
    /// (8), so exhaustive search beats any closed form.
    pub fn select_k(&self, lat: &LatencyModel) -> usize {
        let g = self.gamma.get();
        let mut best_k = 1;
        let mut best = f64::NEG_INFINITY;
        for k in 1..=self.k_max {
            let v = etgr(self.model, g, lat, k);
            if v > best {
                best = v;
                best_k = k;
            }
        }
        best_k
    }

    /// Algorithm 2 step 3: gamma_hat <- (1-mu) gamma_hat + mu (tau/K).
    pub fn observe(&mut self, tau: usize, k: usize) {
        self.gamma.update(tau as f64 / k.max(1) as f64);
    }

    /// Pipelined-drafting depth hook: how many rounds the edge should
    /// keep in flight (1 = sequential, the `serve::pipeline` subsystem's
    /// off switch).
    ///
    /// Pipelining hides the FIXED round cost (propagation + T_base +
    /// headers) behind drafting, so it pays exactly when `T_fixed`
    /// dominates `K * T_marginal`: each extra in-flight round can hide
    /// up to one draft+uplink burst, and `T_fixed / (K * T_marginal)`
    /// bursts fit in one fixed window. But a speculative round only
    /// lands when its whole optimistic prefix holds — full acceptance
    /// AND the predicted bonus token — which happens with probability
    /// ≈ gamma^(K+1) per round; below ~0.2 the retraction traffic
    /// outweighs the hidden RTTs and the hook falls back to sequential.
    pub fn select_pipeline_depth(&self, lat: &LatencyModel, k: usize, max_depth: usize) -> usize {
        let max_depth = max_depth.max(1);
        let k = k.max(1);
        let p_hold = self.gamma.get().max(0.0).powi(k as i32 + 1);
        if p_hold < 0.2 {
            return 1;
        }
        let ratio = lat.t_fixed_ms / (k as f64 * lat.t_marginal_ms).max(1e-9);
        (1 + ratio as usize).min(max_depth)
    }

    /// Resource-aware joint plan (wire v8 device layer, ROADMAP item 4):
    /// stride K, pipeline depth, and draft-tree branching for ONE
    /// session.
    ///
    /// The channel-driven selection above picks a RAW (K, depth) exactly
    /// as before; the device tier's cap table
    /// ([`ComputeTier::plan_caps`]) then bounds it componentwise, and a
    /// draining energy budget walks the session down the same table
    /// (frac >= 0.5 → own tier, >= 0.2 → one tier weaker, below → Weak).
    /// Because the raw plan is tier-independent and the cap table is
    /// componentwise monotone, a weaker tier (or a lower energy
    /// fraction) can NEVER receive a larger plan along any axis — the
    /// property `select_plan_is_monotone_in_tier_and_energy` pins.
    ///
    /// Branching is deliberately a pure function of (tier, energy
    /// fraction, `branching_cap`) and never of the noisy channel sample,
    /// so the live edge and the scheduler sim compute identical trees.
    /// Pipelined rounds keep drafts linear — a retracted speculative
    /// round would have drafted its tree from a poisoned prefix — so
    /// depth > 1 forces branching = 1.
    pub fn select_plan(
        &self,
        lat: &LatencyModel,
        tier: ComputeTier,
        energy_frac: f64,
        max_depth: usize,
        branching_cap: usize,
    ) -> SpecPlan {
        let effective = if energy_frac >= 0.5 {
            tier
        } else if energy_frac >= 0.2 {
            tier.weaker()
        } else {
            ComputeTier::Weak
        };
        let raw_k = self.select_k(lat);
        let raw = SpecPlan {
            k: raw_k,
            depth: self.select_pipeline_depth(lat, raw_k, max_depth),
            branching: branching_cap.max(1),
        };
        let mut plan = raw.min(effective.plan_caps());
        if plan.depth > 1 {
            plan.branching = 1;
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChannelState;
    use crate::devices::{A800_70B, JETSON_ORIN};
    use crate::util::prop;

    fn state(up_mbps: f64, prop_ms: f64) -> ChannelState {
        ChannelState {
            up_bps: up_mbps * 1e6,
            down_bps: up_mbps * 2e6,
            prop_ms,
            fading: false,
            loss_rate: if up_mbps < 1.0 { 0.25 } else if up_mbps < 10.0 { 0.05 } else { 0.005 },
        }
    }

    /// Landscape tests use the Sketch wire — the paper's §III-D
    /// per-token-payload operating point where the channel term is the
    /// lever (FlexSpec's compact wire moves the lever to gamma + fixed
    /// costs; both are exercised by the pipeline tests).
    fn lat(up_mbps: f64, prop_ms: f64) -> LatencyModel {
        LatencyModel::build(
            &state(up_mbps, prop_ms),
            &JETSON_ORIN,
            &A800_70B,
            WireFormat::Sketch,
        )
    }

    #[test]
    fn latency_model_is_affine_in_k() {
        let l = lat(50.0, 95.0);
        assert!((l.step_ms(5) - l.step_ms(0) - 5.0 * l.t_marginal_ms).abs() < 1e-9);
        assert!(l.t_fixed_ms > A800_70B.t_base_ms);
    }

    #[test]
    fn weak_channel_inflates_marginal_cost() {
        let strong = lat(300.0, 18.0);
        let weak = lat(1.5, 180.0);
        assert!(weak.t_marginal_ms > 3.0 * strong.t_marginal_ms);
        assert!(weak.t_fixed_ms > strong.t_fixed_ms);
    }

    #[test]
    fn fig2_kstar_shifts_with_signal_strength() {
        // The paper's Fig. 2: K* small (≈2) in weak signal, large (≈6)
        // in strong signal. "Weak (SNR < 5 dB)" is the deep-fade state:
        // wifi rate / 8, propagation x2.5 (elevator/subway), and the
        // post-evolution acceptance gamma ≈ 0.6 FlexSpec operates at.
        let mut p = AdaptivePolicy::new(8, 0.1);
        p.gamma = Ema::new(0.6, 0.1);
        let k_weak = p.select_k(&lat(1.5 / 8.0, 450.0));
        let k_medium = p.select_k(&lat(50.0, 95.0));
        p.gamma = Ema::new(0.8, 0.1);
        let k_strong = p.select_k(&lat(300.0, 18.0));
        assert!(k_weak <= 3, "weak K* = {k_weak}");
        assert!(k_medium > k_weak, "medium K* = {k_medium}");
        assert!(k_strong >= 6, "strong K* = {k_strong}");
    }

    #[test]
    fn low_acceptance_shrinks_k() {
        let mut p = AdaptivePolicy::new(8, 0.5);
        let l = lat(300.0, 18.0);
        let k_high = p.select_k(&l);
        for _ in 0..30 {
            p.observe(0, 5); // constant rejection
        }
        let k_low = p.select_k(&l);
        assert!(k_low < k_high, "{k_low} !< {k_high}");
        assert!(p.gamma.get() < 0.1);
    }

    #[test]
    fn large_prop_delay_amortizes_toward_larger_k() {
        // §IV-B.2: larger T_fixed incentivizes larger strides.
        let p = AdaptivePolicy::new(8, 0.1);
        let near = p.select_k(&lat(50.0, 10.0));
        let far = p.select_k(&lat(50.0, 400.0));
        assert!(far >= near, "far {far} < near {near}");
    }

    #[test]
    fn geometric_model_is_more_conservative() {
        let l = lat(300.0, 18.0);
        let lin = AdaptivePolicy::new(8, 0.1).with_model(AcceptanceModel::Linear);
        let geo = AdaptivePolicy::new(8, 0.1).with_model(AcceptanceModel::Geometric);
        assert!(geo.select_k(&l) <= lin.select_k(&l));
        // expected tau agrees at K=1
        assert!(
            (expected_tau(AcceptanceModel::Linear, 0.7, 1)
                - expected_tau(AcceptanceModel::Geometric, 0.7, 1))
            .abs()
                < 1e-12
        );
    }

    #[test]
    fn policy_bounds_property() {
        prop::check(200, |rng| {
            let mut p = AdaptivePolicy::new(8, 0.2);
            // random gamma history
            for _ in 0..(rng.next_range(20)) {
                let k = 1 + rng.next_range(8) as usize;
                let tau = rng.next_range(k as u64 + 1) as usize;
                p.observe(tau, k);
            }
            let l = lat(rng.range_f64(0.5, 400.0), rng.range_f64(5.0, 500.0));
            let k = p.select_k(&l);
            prop::assert_prop((1..=8).contains(&k), format!("K*={k} out of range"))?;
            let g = p.gamma.get();
            prop::assert_prop((0.0..=1.0).contains(&g), format!("gamma {g}"))
        });
    }

    #[test]
    fn pipeline_depth_tracks_fixed_cost_dominance() {
        let mut p = AdaptivePolicy::new(8, 0.1);
        p.gamma = Ema::new(0.95, 0.1); // near-aligned draft

        // T_fixed >> K * T_marginal: depth opens up
        let far = LatencyModel {
            t_fixed_ms: 400.0,
            t_marginal_ms: 10.0,
        };
        assert!(p.select_pipeline_depth(&far, 4, 4) >= 2, "far link must pipeline");
        // cap respected
        assert!(p.select_pipeline_depth(&far, 1, 3) <= 3);

        // marginal-dominated link (weak uplink, Sketch-class payloads):
        // pipelining cannot hide anything — sequential
        let near = LatencyModel {
            t_fixed_ms: 20.0,
            t_marginal_ms: 30.0,
        };
        assert_eq!(p.select_pipeline_depth(&near, 4, 4), 1);

        // drifted target (low gamma): speculation almost never holds, so
        // even a fixed-cost-dominated link stays sequential
        let mut drifted = AdaptivePolicy::new(8, 0.1);
        drifted.gamma = Ema::new(0.4, 0.1);
        assert_eq!(drifted.select_pipeline_depth(&far, 4, 4), 1);

        // depth 1 is the floor no matter what
        assert!(p.select_pipeline_depth(&near, 8, 0) >= 1);
    }

    #[test]
    fn select_plan_tracks_the_tier_table() {
        use crate::device::ComputeTier;
        // strong channel + high gamma: the raw plan is large, so the
        // tier caps are what bind.
        let mut p = AdaptivePolicy::new(8, 0.1);
        p.gamma = Ema::new(0.85, 0.1);
        let l = lat(300.0, 18.0);
        let strong = p.select_plan(&l, ComputeTier::Strong, 1.0, 1, 4);
        let mid = p.select_plan(&l, ComputeTier::Mid, 1.0, 1, 4);
        let weak = p.select_plan(&l, ComputeTier::Weak, 1.0, 1, 4);
        assert!(weak.fits_within(mid) && mid.fits_within(strong));
        assert_eq!(weak.branching, 1, "weak edges never draft trees");
        assert_eq!(mid.branching, 2);
        assert_eq!(strong.branching, 4);
        assert_eq!(weak.k, 2);
        assert_eq!(mid.k, 4);
        // a draining battery steps a strong edge down the SAME table
        assert_eq!(p.select_plan(&l, ComputeTier::Strong, 0.3, 1, 4), mid);
        assert_eq!(p.select_plan(&l, ComputeTier::Strong, 0.1, 1, 4), weak);
        // the config cap binds when tighter than the tier cap
        assert_eq!(p.select_plan(&l, ComputeTier::Strong, 1.0, 1, 1).branching, 1);
        // pipelined rounds keep drafts linear
        let far = p.select_plan(&l, ComputeTier::Strong, 1.0, 4, 4);
        if far.depth > 1 {
            assert_eq!(far.branching, 1);
        }
    }

    #[test]
    fn select_plan_is_monotone_in_tier_and_energy() {
        use crate::device::ComputeTier;
        prop::check(300, |rng| {
            let mut p = AdaptivePolicy::new(8, 0.2);
            for _ in 0..rng.next_range(20) {
                let k = 1 + rng.next_range(8) as usize;
                let tau = rng.next_range(k as u64 + 1) as usize;
                p.observe(tau, k);
            }
            let l = lat(rng.range_f64(0.5, 400.0), rng.range_f64(5.0, 500.0));
            let max_depth = 1 + rng.next_range(4) as usize;
            let cap = 1 + rng.next_range(4) as usize;
            let fracs = [0.05, 0.2, 0.35, 0.5, 0.8, 1.0];
            let tiers = ComputeTier::all();
            for (fi, &frac) in fracs.iter().enumerate() {
                for (ti, &tier) in tiers.iter().enumerate() {
                    let plan = p.select_plan(&l, tier, frac, max_depth, cap);
                    prop::assert_prop(
                        plan.fits_within(tier.plan_caps()),
                        format!("{plan:?} exceeds {tier:?} caps"),
                    )?;
                    prop::assert_prop(
                        plan.k >= 1 && plan.depth >= 1 && plan.branching >= 1,
                        format!("degenerate plan {plan:?}"),
                    )?;
                    prop::assert_prop(
                        plan.depth == 1 || plan.branching == 1,
                        format!("pipelined plan must stay linear: {plan:?}"),
                    )?;
                    if ti > 0 {
                        let weaker = p.select_plan(&l, tiers[ti - 1], frac, max_depth, cap);
                        prop::assert_prop(
                            weaker.fits_within(plan),
                            format!("tier monotonicity: {weaker:?} !<= {plan:?}"),
                        )?;
                    }
                    if fi > 0 {
                        let drained = p.select_plan(&l, tier, fracs[fi - 1], max_depth, cap);
                        prop::assert_prop(
                            drained.fits_within(plan),
                            format!("energy monotonicity: {drained:?} !<= {plan:?}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn etgr_matches_hand_computation() {
        let l = LatencyModel {
            t_fixed_ms: 100.0,
            t_marginal_ms: 10.0,
        };
        // gamma=0.5, K=4, linear: (1 + 2)/140
        let v = etgr(AcceptanceModel::Linear, 0.5, &l, 4);
        assert!((v - 3.0 / 140.0).abs() < 1e-12);
    }
}
