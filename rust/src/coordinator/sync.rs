//! Update-storm model synchronization (paper §III-B, Table I).
//!
//! Tightly-coupled SD methods must re-download (or re-train + download)
//! the edge draft whenever the cloud target evolves. This module prices
//! that synchronization: per-user download time over each network class,
//! aggregate traffic for a fleet, and the congestion collapse heuristic
//! the paper's Table I "Scalability" column reports.

use crate::channel::{NetworkKind, NetworkProfile};

/// Size of the paper's compressed edge draft download.
pub const DRAFT_MODEL_BYTES: u64 = 3_200_000_000; // ~3.2 GB

/// Our actual tiny draft bundle size (reported alongside for honesty).
#[derive(Debug, Clone)]
pub struct SyncCost {
    pub network: NetworkKind,
    pub bandwidth_label: String,
    /// One user, one update.
    pub one_user_minutes: f64,
    /// Aggregate traffic for `users` clients, one update (bytes).
    pub fleet_bytes: u64,
    /// Qualitative scalability verdict (Table I's third column).
    pub scalability: &'static str,
}

/// Cell capacity assumption for the congestion verdict: how many
/// concurrent full-rate downloads a base station sustains.
fn concurrent_capacity(kind: NetworkKind) -> f64 {
    match kind {
        NetworkKind::FiveG => 40.0,
        NetworkKind::FourG => 12.0,
        NetworkKind::WifiWeak => 3.0,
    }
}

pub fn sync_cost(kind: NetworkKind, users: u64, model_bytes: u64) -> SyncCost {
    let p = NetworkProfile::new(kind);
    let minutes = p.sync_minutes(model_bytes);
    let capacity = concurrent_capacity(kind);
    // minutes of cell-saturation per update wave
    let saturation_min = minutes * users as f64 / capacity;
    let scalability = if saturation_min > 8.0 * 60.0 {
        "Collapse / High Congestion"
    } else if saturation_min > 60.0 {
        "High Congestion"
    } else if saturation_min > 10.0 {
        "Moderate Load"
    } else {
        "OK"
    };
    SyncCost {
        network: kind,
        bandwidth_label: format!("{:.0} Mbps", p.down_bps / 1e6),
        one_user_minutes: minutes,
        fleet_bytes: model_bytes * users,
        scalability,
    }
}

/// Update-related traffic of a method over an evaluation horizon
/// (Table I + the RQ1 "Sync Required?" row).
#[derive(Debug, Clone)]
pub struct UpdateTraffic {
    pub method: &'static str,
    pub sync_required: bool,
    pub bytes_per_update_per_user: u64,
}

pub fn method_update_traffic(method: &str) -> UpdateTraffic {
    // EAGLE-2 expansion layers / Medusa heads are smaller than a full
    // draft but still hundreds of MB at 70B scale; Std SD re-downloads a
    // full draft; FlexSpec / PLD / Lookahead / Cloud-Only ship nothing.
    match method {
        "eagle2" => UpdateTraffic {
            method: "EAGLE-2 (Synced)",
            sync_required: true,
            bytes_per_update_per_user: 900_000_000,
        },
        "medusa" => UpdateTraffic {
            method: "Medusa-1 (Synced)",
            sync_required: true,
            bytes_per_update_per_user: 600_000_000,
        },
        "std_sd" => UpdateTraffic {
            method: "Std. SD (if synced)",
            sync_required: true,
            bytes_per_update_per_user: DRAFT_MODEL_BYTES,
        },
        "flexspec" => UpdateTraffic {
            method: "FlexSpec",
            sync_required: false,
            bytes_per_update_per_user: 0,
        },
        _ => UpdateTraffic {
            method: "model-free",
            sync_required: false,
            bytes_per_update_per_user: 0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_one_user_times() {
        // Paper Table I (10/50/300 Mbps): ~48 / ~9.5 / ~1.6 minutes.
        // Our profiles' downlinks are 4/100/600 Mbps; times scale as
        // bytes*8/rate — check the 4G/5G anchors within ~2x and the
        // ordering everywhere.
        let wifi = sync_cost(NetworkKind::WifiWeak, 1, DRAFT_MODEL_BYTES);
        let lte = sync_cost(NetworkKind::FourG, 1, DRAFT_MODEL_BYTES);
        let g5 = sync_cost(NetworkKind::FiveG, 1, DRAFT_MODEL_BYTES);
        assert!(wifi.one_user_minutes > lte.one_user_minutes);
        assert!(lte.one_user_minutes > g5.one_user_minutes);
        assert!(g5.one_user_minutes < 2.0, "{}", g5.one_user_minutes);
        assert!(wifi.one_user_minutes > 48.0, "{}", wifi.one_user_minutes);
    }

    #[test]
    fn fleet_scalability_verdicts() {
        let wifi = sync_cost(NetworkKind::WifiWeak, 1000, DRAFT_MODEL_BYTES);
        assert!(wifi.scalability.contains("Collapse"), "{}", wifi.scalability);
        let g5 = sync_cost(NetworkKind::FiveG, 1000, DRAFT_MODEL_BYTES);
        assert!(!g5.scalability.contains("Collapse"));
        assert_eq!(wifi.fleet_bytes, DRAFT_MODEL_BYTES * 1000);
    }

    #[test]
    fn flexspec_ships_nothing() {
        assert_eq!(method_update_traffic("flexspec").bytes_per_update_per_user, 0);
        assert!(!method_update_traffic("flexspec").sync_required);
        assert!(method_update_traffic("eagle2").sync_required);
        assert!(
            method_update_traffic("std_sd").bytes_per_update_per_user
                > method_update_traffic("eagle2").bytes_per_update_per_user
        );
    }
}
