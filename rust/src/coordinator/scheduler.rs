//! Multi-user serving: request router + dynamic verification batcher.
//!
//! The cloud amortizes its fixed per-step cost T_base across concurrent
//! sessions by collecting verify requests inside a batching window
//! (vLLM-style continuous batching, applied to verification blocks).
//! A discrete-event simulation advances virtual time; model execution is
//! real (PJRT) and happens when events are processed.
//!
//! The batching window and per-session commit bookkeeping live in
//! `serve::session` and are SHARED with the real server
//! (`serve::verifier`): the simulator and the loopback/TCP serving paths
//! run the same state machine, which is what makes their token counts
//! comparable. `serve_with` is the generic entry (any `VerifyBackend`,
//! any `DraftSource` factory); `serve` is the original PJRT wrapper.

use super::edge::{DraftSource, ModelDraft};
use super::policy::{AdaptivePolicy, LatencyModel};
use crate::channel::{Channel, StochasticChannel};
use crate::channel::profiles::NetworkProfile;
use crate::device::DeviceProfile;
use crate::devices::{CloudProfile, EdgeDevice};
use crate::energy::EnergyBudget;
use crate::obs::{LatencySummary, SpanKind, Trace};
use crate::protocol::{DraftMsg, VerifyMode, VerifyMsg, WireFormat};
use crate::runtime::ModelRuntime;
#[cfg(test)]
use crate::runtime::Registry;
use crate::serve::backend::{bucket_k, BackendVerdict, BatchVerifyReq, VerifyBackend};
use crate::serve::session::{BatchDecision, BatchWindow, SessionCore, SessionOutcome};
use crate::util::rng::SplitMix64;
use crate::util::stats::Summary;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::rc::Rc;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    /// A session's uplink draft block arrives at the cloud.
    RequestArrives(u32),
    /// The open batch window closes. Carries the window epoch the timer
    /// was armed for: if a `CloseNow` already drained that window, the
    /// stale timer must not truncate the NEXT window (`BatchWindow`
    /// epoch docs).
    BatchClose(u64),
    /// A new user session arrives.
    SessionArrives(u32),
}

#[derive(Debug, Clone, Copy)]
struct Scheduled {
    at_ms: f64,
    seq: u64,
    ev: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at_ms.total_cmp(&other.at_ms).is_eq() && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp: a poisoned (NaN) event time must order, not panic
        // the whole event loop
        self.at_ms
            .total_cmp(&other.at_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

struct SessionState {
    core: SessionCore,
    draft: Box<dyn DraftSource>,
    channel: StochasticChannel,
    policy: AdaptivePolicy,
    started_ms: f64,
    /// Hetero twin (wire v8): the session's device profile, if the run
    /// models a heterogeneous population. `None` = unprofiled, which
    /// reduces EXACTLY to the v7 drafting path.
    profile: Option<DeviceProfile>,
    /// Per-session energy meter, charged per drafted tree node exactly
    /// like the live edge's `LinkStats` (same `charge_draft` inputs ⇒
    /// the same remaining fraction feeds `select_plan` on both sides).
    energy: EnergyBudget,
    /// In-flight proposal awaiting verification: (tokens, chosen_probs,
    /// prob_rows, tree parents). `parents` empty = linear draft.
    pending: Option<(Vec<i32>, Vec<f32>, Vec<Vec<f32>>, Vec<u8>)>,
    /// Pipelined mode: the NEXT round's speculative draft, launched
    /// while `pending` verifies (mirrors `serve::pipeline`'s depth-2
    /// in-flight window under the virtual clock).
    spec_next: Option<SpecDraft>,
    /// Fleet twin: handoffs this session has survived.
    redirects: usize,
    /// Virtual time the pending draft was admitted to the batching
    /// window (queue-wait measurement).
    arrived_ms: f64,
    /// Virtual time the pending draft left the edge (RTT measurement).
    sent_ms: f64,
    rng: SplitMix64,
}

/// One speculative round in flight (virtual-clock twin of
/// `serve::pipeline::InflightRound`).
struct SpecDraft {
    round: u32,
    tokens: Vec<i32>,
    chosen_probs: Vec<f32>,
    prob_rows: Vec<Vec<f32>>,
    /// The bonus token the PREVIOUS round's speculation bet on — the
    /// validity link: this draft survives iff that round fully accepts
    /// AND commits exactly this correction.
    link_bonus: i32,
    /// This round's own predicted bonus — the chain link for the round
    /// after it.
    own_bonus: Option<i32>,
    /// Virtual time the draft reaches the cloud.
    arrive_ms: f64,
    /// Virtual time the draft left the edge (RTT measurement).
    sent_ms: f64,
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub window_ms: f64,
    pub max_batch: usize,
    pub users: usize,
    /// Mean inter-arrival time of new sessions, ms (exponential).
    pub arrival_mean_ms: f64,
    pub max_new: usize,
    pub mode: VerifyMode,
    pub temperature: f32,
    pub top_p: f32,
    pub seed: u64,
    /// Pin the stride instead of running the adaptive policy — the knob
    /// that makes sim ↔ loopback ↔ TCP token counts bit-comparable.
    pub fixed_k: Option<usize>,
    /// End a session when fewer KV slots than this remain. MUST match
    /// `serve::VerifierConfig::capacity_floor` for sim ↔ serve count
    /// equality.
    pub capacity_floor: usize,
    /// Pipelined drafting (`serve::pipeline` twin): 1 = sequential
    /// lock-step; >= 2 overlaps the next round's draft + uplink with the
    /// current round's verify + downlink, cancel-on-reject. The
    /// simulator models ONE speculative round in flight (the serving
    /// stack's depth-2 shape); committed sequences are identical either
    /// way, and with `fixed_k` the pipeline counters match the serving
    /// stack's exactly. Requires a pure draft source.
    pub pipeline_depth: usize,
    /// Admission-control mirror of `serve::VerifierConfig::
    /// admission_queue`: a draft arriving while this many drafts are
    /// already pending verification is turned away (the serving
    /// stack's `Busy` frame) and re-arrives after one batching window.
    /// Committed sequences are unchanged — drafts are pure functions of
    /// the committed prefix, so deferral only moves virtual wall time.
    /// MUST match the serving config for sim ↔ serve comparability.
    /// 0 (default) = unbounded; effective values are `1..max_batch`
    /// (the window drains at `max_batch`, so larger bounds never
    /// trigger — see the serving-side doc).
    pub admission_queue: usize,
    /// Fleet twin (`serve::fleet`): `None` (default) = single replica.
    /// `Some` replays a deterministic redirect schedule — each session
    /// is handed to the next replica after a fixed number of verified
    /// rounds, paying the handoff's control round trips in virtual
    /// time. Committed sequences are UNCHANGED (drafts and synthetic
    /// verdicts are pure functions of the committed prefix), which is
    /// the fleet determinism claim `tests/serve_fleet.rs` pins.
    pub fleet: Option<FleetSimConfig>,
    /// Trace journal (usually on a [`crate::obs::VirtualClock`] the
    /// event loop advances). The sim emits the SAME canonical
    /// per-session event sequence the serving stack does — the
    /// determinism contract extended to observability
    /// (`tests/serve_obs.rs`). `None` (default) records nothing.
    pub trace: Option<Trace>,
    /// Hetero twin (wire v8): per-session device profiles. `None`
    /// (default) leaves every session unprofiled — drafting, policy and
    /// energy behave exactly as in v7. `Some(ps)` assigns session `i`
    /// the profile `ps[i % ps.len()]`: its device sets the virtual
    /// draft cost, its tier caps the speculation plan, and its energy
    /// budget is metered per drafted node. Feed the SAME vector to the
    /// live stack's per-session `EdgeSessionConfig`s for sim ↔ serve
    /// comparability (`tests/serve_hetero.rs`).
    pub profiles: Option<Vec<DeviceProfile>>,
    /// Draft-tree branching cap (wire v8). 1 (default) = linear
    /// drafting, byte-identical to v7. >1 lets PROFILED greedy
    /// sequential sessions draft a token tree up to this wide at each
    /// bucket-aligned chain position; the batcher flattens root→leaf
    /// paths into ragged rows and commits the deepest accepted path
    /// (ties to the main chain), mirroring `VerifierCore::close_window`.
    /// The effective width is still capped by the session tier's
    /// `plan_caps` — a Weak device drafts linearly no matter the cap.
    /// Stochastic modes and pipelined rounds stay linear, like the live
    /// edge.
    pub branching: usize,
}

/// Virtual-clock twin of the live fleet's redirect schedule (see
/// [`ServeConfig::fleet`]). Versions are fleet-uniform in the twin —
/// per-replica version evolution is a live-stack concern (the sim's
/// single backend plays every replica); the twin models HANDOFF TIMING
/// (which replica serves a round is unobservable to a pure backend, so
/// placement itself has no simulated state).
#[derive(Debug, Clone)]
pub struct FleetSimConfig {
    /// Replica count — gates the schedule (a 1-replica fleet never
    /// redirects).
    pub replicas: usize,
    /// Hand a session to the next replica after this many verified
    /// rounds (0 = never redirect).
    pub redirect_after_rounds: usize,
    /// Handoffs per session before it settles (the live drain redirects
    /// a session at most once per replica per grace window; 1 mirrors
    /// the common drain).
    pub max_redirects: usize,
    /// Virtual cost of one handoff, ms (redial + Hello/HelloAck +
    /// Resume/ResumeAck control round trips). A FLAT figure by design:
    /// sampling the session's channel here would advance its RNG stream
    /// and change adaptive-K stride choices — the handoff must move
    /// wall time only.
    pub handoff_ms: f64,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        FleetSimConfig {
            replicas: 2,
            redirect_after_rounds: 3,
            max_redirects: 1,
            handoff_ms: 40.0,
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            window_ms: 12.0,
            max_batch: 8,
            users: 8,
            arrival_mean_ms: 400.0,
            max_new: 32,
            mode: VerifyMode::Greedy,
            temperature: 0.0,
            top_p: 1.0,
            seed: 1,
            fixed_k: None,
            capacity_floor: 10,
            pipeline_depth: 1,
            admission_queue: 0,
            fleet: None,
            trace: None,
            profiles: None,
            branching: 1,
        }
    }
}

/// Aggregate serving report.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Sessions decoded to completion.
    pub completed: usize,
    /// Virtual wall time of the last completion, ms.
    pub wall_ms: f64,
    /// Committed tokens (accepted + correction/bonus) across sessions.
    pub tokens: usize,
    /// Verified rounds across sessions.
    pub rounds: usize,
    /// Verification batches closed.
    pub batches: usize,
    /// Mean verify requests per closed batch.
    pub mean_batch: f64,
    /// Per-request latency (arrival → final verdict delivered), ms.
    pub request_latency: Summary,
    /// Request latency divided by tokens generated, ms/token.
    pub per_token_latency: Summary,
    /// Per-session acceptance rates (sessions that drafted ≥ 1 token).
    pub acceptance: Summary,
    /// Fixed per-step cloud cost amortized away by batching: T_base ×
    /// (batch occupancy − 1), summed over batches.
    pub t_base_saved_ms: f64,
    /// Rounds verified from a speculative draft whose optimistic prefix
    /// held (pipelined mode) — round trips hidden under the virtual
    /// clock. Matches `ServingMetrics::rounds_pipelined` for the same
    /// seed and fixed stride.
    pub rounds_pipelined: usize,
    /// Speculative rounds whose prefix broke: retracted and redrafted.
    pub drafts_cancelled: usize,
    /// Draft tokens of retracted speculative rounds.
    pub draft_tokens_wasted: usize,
    /// Drafts turned away at the admission-queue bound and re-arrived
    /// after the retry horizon (the serving stack's `Busy` deferrals).
    pub drafts_busy_deferred: usize,
    /// Fleet twin: sessions handed to another replica mid-decode (the
    /// serving stack's `Redirect`/export/import path). Handoffs move
    /// virtual wall time, never a committed token.
    pub sessions_redirected: usize,
    /// Verification ROWS executed across closed batches (a linear draft
    /// is one row; a tree draft is one row per root→leaf path). Mirrors
    /// `ServingMetrics::verify_rows`.
    pub verify_rows: usize,
    /// Rounds whose verified draft was a token tree (wire v8). Mirrors
    /// `ServingMetrics::tree_rounds`.
    pub tree_rounds: usize,
    /// Stacked `[B, K]` device dispatches across closed batches: one
    /// per distinct planner bucket class per greedy batch (mirrors
    /// `ServingMetrics::stacked_dispatches`). Bucket-aligned tree combs
    /// add rows WITHOUT adding classes — the hetero bench gates
    /// accepted-per-dispatch on exactly this counter.
    pub stacked_dispatches: usize,
    /// Sessions per device compute tier (weak / mid / strong); all
    /// zeros when the run is unprofiled. Mirrors
    /// `ServingMetrics::sessions_by_device_tier`.
    pub sessions_by_tier: [usize; 3],
    /// Per-session final counters, in prompt order (for cross-checking
    /// against loopback/TCP serving runs).
    pub per_session: Vec<SessionOutcome>,
    /// Per-session committed sequences (prompt + generated), aligned
    /// with `per_session` — the reference trajectory the fault-injection
    /// serving tests compare reconnect-and-resume runs against.
    pub per_session_committed: Vec<Vec<i32>>,
    /// Virtual-time latency histograms mirroring the serving stack's
    /// `ServingMetrics::latency` (queue wait, verify execution,
    /// per-round, and edge-observed RTT).
    pub latency: LatencySummary,
}

impl ServeReport {
    pub fn throughput_tok_s(&self) -> f64 {
        self.tokens as f64 / (self.wall_ms / 1e3).max(1e-9)
    }
}

/// Edge: draft + uplink; returns the virtual arrival time at the cloud.
/// In pipelined mode (`cfg.pipeline_depth >= 2`, pure draft source) it
/// also launches the NEXT round's speculative draft from the optimistic
/// prefix, exactly as the serving edge does right after sending.
fn draft_and_send(
    s: &mut SessionState,
    now: f64,
    device: &EdgeDevice,
    cfg: &ServeConfig,
    cloud_profile: &CloudProfile,
) -> Result<f64> {
    // a profiled session drafts on ITS device (the tier's
    // representative) — the fleet-wide `device` is the unprofiled
    // default, exactly as the live edge runs on its own hardware
    let device = s.profile.map_or(device, |p| p.device);
    let chan = s.channel.sample(now);
    let lat = LatencyModel::build(&chan, device, cloud_profile, WireFormat::Compact);
    // plan selection mirrors the live `LinkStats::select_plan`:
    // unprofiled = the v7 stride policy verbatim; profiled = the joint
    // (K, depth, branching) policy under tier caps + remaining energy,
    // with `fixed_k` overriding the stride (never the branching) and
    // stochastic / pipelined rounds forced linear.
    let (k, branching) = if let Some(p) = s.profile {
        let mut plan = s.policy.select_plan(
            &lat,
            p.tier,
            s.energy.remaining_frac(),
            1,
            cfg.branching.max(1),
        );
        if let Some(k) = cfg.fixed_k {
            plan.k = k;
        }
        plan.k = plan.k.clamp(1, 8);
        if cfg.mode != VerifyMode::Greedy || cfg.pipeline_depth > 1 {
            plan.branching = 1;
        }
        (plan.k, plan.branching)
    } else {
        let k = cfg
            .fixed_k
            .unwrap_or_else(|| s.policy.select_k(&lat))
            .clamp(1, 8);
        (k, 1)
    };
    let (tokens, chosen_probs, prob_rows, parents, edge_tokens) = if branching > 1 {
        let tp = s.draft.propose_tree(
            &s.core.committed,
            k,
            branching,
            cfg.temperature,
            cfg.top_p,
            &mut s.rng,
        )?;
        let n = tp.edge_tokens;
        (tp.tokens, vec![], vec![], tp.parents, n)
    } else {
        let prop =
            s.draft
                .propose(&s.core.committed, k, cfg.temperature, cfg.top_p, &mut s.rng)?;
        let n = prop.edge_tokens;
        (prop.tokens, prop.chosen_probs, prop.prob_rows, vec![], n)
    };
    if let Some(p) = s.profile {
        // same charge the live edge applies: one draft forward per node
        s.energy.charge_draft(p.device, tokens.len());
    }
    let t_edge = device.round_overhead_ms + edge_tokens as f64 * device.draft_ms_per_token;
    let msg = DraftMsg {
        session: s.core.id,
        round: s.core.rounds as u32,
        tokens: tokens.clone(),
        chosen_probs: chosen_probs.clone(),
        mode: cfg.mode,
        wire: WireFormat::Compact,
        basis_len: 0,
        spec: vec![],
        tree: parents.clone(),
    };
    let t_up = chan.prop_ms + chan.up_ms(msg.air_bytes());
    let arrive = now + t_edge + t_up;
    let head_tokens = tokens.clone();
    let head_round = s.core.rounds as u32;
    // one Draft + Uplink per LAUNCH, exactly like the serving edge (a
    // Busy re-arrival later records nothing)
    if let Some(tr) = &cfg.trace {
        tr.record(s.core.id, head_round, SpanKind::Draft, t_edge, head_tokens.len() as u32, 0);
        tr.record(s.core.id, head_round, SpanKind::Uplink, t_up, msg.air_bytes() as u32, 0);
    }
    s.sent_ms = now + t_edge;
    s.pending = Some((tokens, chosen_probs, prob_rows, parents));
    s.spec_next = None;
    if cfg.pipeline_depth > 1 && s.draft.is_pure() && !head_tokens.is_empty() {
        // predict the bonus token (the +1 every round commits) — the
        // validity link the speculation bets on
        let mut ctx = s.core.committed.clone();
        ctx.extend_from_slice(&head_tokens);
        let bonus = s
            .draft
            .propose(&ctx, 1, cfg.temperature, cfg.top_p, &mut s.rng)?
            .tokens
            .first()
            .copied();
        if let Some(b) = bonus {
            launch_spec(s, arrive, &head_tokens, b, head_round + 1, device, cfg, cloud_profile)?;
        }
    }
    Ok(arrive)
}

/// Pipelined mode: draft round `round` from the optimistic prefix
/// `committed ++ head_tokens ++ head_bonus` and put it in flight.
/// Mirrors `serve::pipeline::PipelinedDrafter`'s launch gates exactly
/// (same gates ⇒ identical pipeline counters in sim and serve for a
/// fixed stride). `launch_ms` is when the edge starts drafting it.
#[allow(clippy::too_many_arguments)]
fn launch_spec(
    s: &mut SessionState,
    launch_ms: f64,
    head_tokens: &[i32],
    head_bonus: i32,
    round: u32,
    device: &EdgeDevice,
    cfg: &ServeConfig,
    cloud_profile: &CloudProfile,
) -> Result<()> {
    s.spec_next = None;
    let device = s.profile.map_or(device, |p| p.device);
    // optimistic budget gate (PipelinedDrafter::can_launch): a round
    // that could only exist if the speculation FAILS is never drafted
    let optimistic_new = s.core.committed.len() + head_tokens.len() + 1 - s.core.prompt_len;
    if optimistic_new >= cfg.max_new {
        return Ok(());
    }
    let mut ctx = s.core.committed.clone();
    ctx.extend_from_slice(head_tokens);
    ctx.push(head_bonus);
    let chan = s.channel.sample(launch_ms);
    let lat = LatencyModel::build(&chan, device, cloud_profile, WireFormat::Compact);
    let k = cfg
        .fixed_k
        .unwrap_or_else(|| s.policy.select_k(&lat))
        .clamp(1, 8);
    let prop = s
        .draft
        .propose(&ctx, k, cfg.temperature, cfg.top_p, &mut s.rng)?;
    if prop.tokens.is_empty() {
        return Ok(());
    }
    if let Some(p) = s.profile {
        s.energy.charge_draft(p.device, prop.tokens.len());
    }
    // this round's own bonus chains the round after it
    let own_bonus = {
        let mut ctx2 = ctx.clone();
        ctx2.extend_from_slice(&prop.tokens);
        s.draft
            .propose(&ctx2, 1, cfg.temperature, cfg.top_p, &mut s.rng)?
            .tokens
            .first()
            .copied()
    };
    // wire shape (basis + spec tail) only matters for byte accounting
    let spec_suffix: Vec<i32> = head_tokens.iter().copied().chain([head_bonus]).collect();
    let msg = DraftMsg {
        session: s.core.id,
        round,
        tokens: prop.tokens.clone(),
        chosen_probs: prop.chosen_probs.clone(),
        mode: cfg.mode,
        wire: WireFormat::Compact,
        basis_len: s.core.committed.len() as u64,
        spec: spec_suffix,
        tree: vec![],
    };
    let t_edge = device.round_overhead_ms + prop.edge_tokens as f64 * device.draft_ms_per_token;
    let t_up = chan.prop_ms + chan.up_ms(msg.air_bytes());
    // a speculative launch records like any other; if its prefix later
    // breaks, the redraft records again under the same round number —
    // the serving edge's per-launch semantics
    if let Some(tr) = &cfg.trace {
        tr.record(s.core.id, round, SpanKind::Draft, t_edge, prop.tokens.len() as u32, 0);
        tr.record(s.core.id, round, SpanKind::Uplink, t_up, msg.air_bytes() as u32, 0);
    }
    s.spec_next = Some(SpecDraft {
        round,
        tokens: prop.tokens,
        chosen_probs: prop.chosen_probs,
        prob_rows: prop.prob_rows,
        link_bonus: head_bonus,
        own_bonus,
        arrive_ms: launch_ms + t_edge + t_up,
        sent_ms: launch_ms + t_edge,
    });
    Ok(())
}

/// Run a multi-user serving simulation with dynamic verification
/// batching over ANY verification backend and draft source. Prompts are
/// provided per user (generated by the workload layer); `make_draft` is
/// called once per session.
#[allow(clippy::too_many_arguments)]
pub fn serve_with(
    backend: &mut dyn VerifyBackend,
    make_draft: &mut dyn FnMut(u32) -> Result<Box<dyn DraftSource>>,
    prompts: &[Vec<i32>],
    device: &EdgeDevice,
    cloud_profile: &CloudProfile,
    net: &NetworkProfile,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut heap: BinaryHeap<Reverse<Scheduled>> = BinaryHeap::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<Reverse<Scheduled>>, at_ms: f64, ev: Event, seq: &mut u64| {
        *seq += 1;
        heap.push(Reverse(Scheduled { at_ms, seq: *seq, ev }));
    };

    let mut arrival_rng = SplitMix64::new(cfg.seed ^ 0xA881);
    let mut sessions: Vec<SessionState> = Vec::new();
    let mut t_arrive = 0.0;
    let mut window = BatchWindow::new(cfg.window_ms, cfg.max_batch);
    let mut report = ServeReport::default();
    for (i, prompt) in prompts.iter().take(cfg.users).enumerate() {
        let id = (i + 1) as u32;
        let mut draft = make_draft(id)?;
        // same session-start notification the edge client gives its
        // draft (PLD needs the prompt/generation boundary)
        draft.on_prompt(prompt.len());
        // hetero twin: session i wears profile i (mod len) — feed the
        // live stack the same vector and the populations line up
        let profile = cfg
            .profiles
            .as_ref()
            .filter(|ps| !ps.is_empty())
            .map(|ps| ps[i % ps.len()]);
        if let Some(p) = profile {
            if let Some(slot) = report.sessions_by_tier.get_mut(p.tier.code() as usize) {
                *slot += 1;
            }
        }
        sessions.push(SessionState {
            core: SessionCore::new(id, prompt, cfg.max_new),
            draft,
            channel: net.channel(cfg.seed ^ (0x1000 + id as u64)),
            policy: AdaptivePolicy::new(8, 0.15),
            started_ms: 0.0,
            profile,
            energy: profile.map_or(EnergyBudget::unmetered(), |p| {
                EnergyBudget::new(p.energy_budget_j)
            }),
            pending: None,
            spec_next: None,
            redirects: 0,
            arrived_ms: 0.0,
            sent_ms: 0.0,
            rng: SplitMix64::new(cfg.seed ^ (0x2000 + id as u64)),
        });
        push(&mut heap, t_arrive, Event::SessionArrives(id), &mut seq);
        t_arrive += arrival_rng.next_exp(1.0 / cfg.arrival_mean_ms);
    }
    // Greedy batched verification ignores the sampling stream entirely
    // (both the synthetic target and the stacked engine path); this rng
    // exists only to satisfy the verify_batch signature. Stochastic
    // mode never reaches it — it keeps the per-session streams below.
    let mut batch_rng = SplitMix64::new(cfg.seed ^ 0x0BA7_C4E6);
    #[allow(unused_assignments)]
    let mut now = 0.0f64;

    while let Some(Reverse(Scheduled { at_ms, ev, .. })) = heap.pop() {
        now = at_ms;
        if let Some(tr) = &cfg.trace {
            // drive the trace's (virtual) clock so event timestamps
            // read simulated time, not wall time
            tr.clock().advance_to(now);
        }
        match ev {
            Event::SessionArrives(id) => {
                let s = &mut sessions[(id - 1) as usize];
                s.started_ms = now;
                backend.start_session(id, &s.core.committed.clone())?;
                let arrive = draft_and_send(
                    s,
                    now + cloud_profile.prefill_ms(s.core.prompt_len),
                    device,
                    cfg,
                    cloud_profile,
                )?;
                push(&mut heap, arrive, Event::RequestArrives(id), &mut seq);
            }
            Event::RequestArrives(id) => {
                // fleet twin: after the scheduled number of verified
                // rounds the session is handed to the next replica —
                // the arriving draft is held while the edge redials and
                // resumes (two control round trips of virtual air
                // time), then re-arrives at the peer. The draft bytes
                // are unchanged (pure function of the committed
                // prefix), so the handoff moves wall time only — the
                // live stack's export/Redirect/import path under the
                // virtual clock.
                if let Some(fl) = &cfg.fleet {
                    let s = &mut sessions[(id - 1) as usize];
                    if fl.replicas > 1
                        && fl.redirect_after_rounds > 0
                        && s.redirects < fl.max_redirects
                        && s.core.rounds >= fl.redirect_after_rounds * (s.redirects + 1)
                    {
                        s.redirects += 1;
                        report.sessions_redirected += 1;
                        // the live stack's exporter records Redirect +
                        // Export; the importer records Import — one
                        // handoff, three events, same round number
                        if let Some(tr) = &cfg.trace {
                            let round = s.core.rounds as u32;
                            tr.record(id, round, SpanKind::Redirect, 0.0, 0, 0);
                            tr.record(id, round, SpanKind::Export, 0.0, 0, 0);
                            tr.record(id, round, SpanKind::Import, fl.handoff_ms.max(0.0), 0, 0);
                        }
                        // in-flight speculation dies with the handoff
                        // (the live edge resets its pipe on reattach)
                        // and is re-launched after the resume.
                        // The handoff cost is a FLAT configured figure,
                        // deliberately not drawn from the session's
                        // channel stream: `StochasticChannel::sample`
                        // advances per-session RNG state, and an extra
                        // draw here would shift every later round's
                        // sample — with adaptive K that changes stride
                        // choices and breaks the tokens-never-change
                        // invariant this twin exists to pin.
                        s.spec_next = None;
                        push(
                            &mut heap,
                            now + fl.handoff_ms.max(0.0),
                            Event::RequestArrives(id),
                            &mut seq,
                        );
                        continue;
                    }
                }
                // admission-control mirror: at the backlog bound the
                // draft is turned away (a Busy on the wire) and
                // re-arrives after one batching window — the same
                // retry horizon the live edge backs off to. The head
                // promotion shortcut of the serving stack has no sim
                // twin, so under saturation only COMMITTED SEQUENCES
                // (not busy counts) are comparable sim ↔ serve.
                if cfg.admission_queue > 0 && window.len() >= cfg.admission_queue {
                    report.drafts_busy_deferred += 1;
                    push(
                        &mut heap,
                        now + cfg.window_ms.max(1.0),
                        Event::RequestArrives(id),
                        &mut seq,
                    );
                    continue;
                }
                sessions[(id - 1) as usize].arrived_ms = now;
                match window.offer(now, id) {
                    BatchDecision::CloseNow => {
                        push(&mut heap, now, Event::BatchClose(window.epoch()), &mut seq)
                    }
                    BatchDecision::CloseAt(t) => {
                        push(&mut heap, t, Event::BatchClose(window.epoch()), &mut seq)
                    }
                    BatchDecision::Queued => {}
                }
            }
            Event::BatchClose(epoch) => {
                if epoch != window.epoch() {
                    continue; // stale timer from an already-drained window
                }
                let members = window.close();
                if members.is_empty() {
                    continue;
                }
                report.batches += 1;
                report.mean_batch += members.len() as f64;

                // take every member's pending draft, then verify the
                // whole window through the SAME batched executor entry
                // the live verifier drives (`verify_batch`: planner
                // buckets → stacked [B, K] forwards, one amortized
                // T_base per bucket). Stochastic mode keeps the
                // sequential loop — it consumes per-session sampling
                // streams in member order, which stacking would break.
                let mut taken: Vec<(u32, Vec<i32>, Vec<Vec<f32>>, Vec<u8>)> =
                    Vec::with_capacity(members.len());
                for &id in &members {
                    let s = &mut sessions[(id - 1) as usize];
                    let (tokens, _probs, rows, parents) = s.pending.take().unwrap();
                    taken.push((id, tokens, rows, parents));
                }
                let batch = taken.len();
                let total_draft: usize = taken.iter().map(|(_, t, _, _)| t.len()).sum();
                let max_k = taken.iter().map(|(_, t, _, _)| t.len()).max().unwrap_or(0);
                let mut total_tokens = 0usize;
                // verdict per member: (id, applied draft, verdict,
                // winning leaf, was-a-tree-round)
                let mut verdicts: Vec<(u32, Vec<i32>, BackendVerdict, Option<u8>, bool)> =
                    Vec::with_capacity(taken.len());
                if cfg.mode == VerifyMode::Greedy {
                    // expand: tree drafts fan out into one row per
                    // root→leaf path, ascending leaf order (the main
                    // chain first), mirroring `VerifierCore::
                    // close_window`. Backends whose per-session rows
                    // are not independent verify only the first root
                    // path and stay effectively linear.
                    let tree_ok = backend.supports_tree_rows();
                    let mut rows_plan: Vec<(usize, Option<u8>, Option<Vec<i32>>)> =
                        Vec::with_capacity(taken.len());
                    for (ji, (id, tokens, _rows, parents)) in taken.iter().enumerate() {
                        if parents.is_empty() {
                            rows_plan.push((ji, None, None));
                            continue;
                        }
                        let tmsg = DraftMsg {
                            session: *id,
                            round: 0,
                            tokens: tokens.clone(),
                            chosen_probs: vec![],
                            mode: cfg.mode,
                            wire: WireFormat::Compact,
                            basis_len: 0,
                            spec: vec![],
                            tree: parents.clone(),
                        };
                        let leaves = tmsg.tree_leaves();
                        let fan = if tree_ok { leaves.len() } else { 1 };
                        for &leaf in leaves.iter().take(fan) {
                            rows_plan.push((ji, Some(leaf), Some(tmsg.tree_path(leaf))));
                        }
                    }
                    report.verify_rows += rows_plan.len();
                    // one stacked [B, K] dispatch per distinct planner
                    // bucket class, counted over ROWS (bucket-aligned
                    // combs add rows, not classes)
                    report.stacked_dispatches += {
                        let mut kinds: Vec<usize> = rows_plan
                            .iter()
                            .map(|(ji, _, path)| {
                                bucket_k(path.as_ref().map_or(taken[*ji].1.len(), Vec::len))
                            })
                            .collect();
                        kinds.sort_unstable();
                        kinds.dedup();
                        kinds.len()
                    };
                    let reqs: Vec<BatchVerifyReq> = rows_plan
                        .iter()
                        .map(|(ji, _, path)| BatchVerifyReq {
                            id: taken[*ji].0,
                            committed: &sessions[(taken[*ji].0 - 1) as usize].core.committed,
                            draft: path.as_deref().unwrap_or(&taken[*ji].1),
                            mode: cfg.mode,
                        })
                        .collect();
                    let vs =
                        backend.verify_batch(&reqs, cfg.temperature, cfg.top_p, &mut batch_rng)?;
                    drop(reqs);
                    total_tokens += rows_plan
                        .iter()
                        .map(|(ji, _, path)| {
                            path.as_ref().map_or(taken[*ji].1.len(), Vec::len) + 1
                        })
                        .sum::<usize>();
                    // reduce each member's rows to one verdict: deepest
                    // accepted prefix (max tau) wins, ties break toward
                    // the SMALLEST row index — a drift-free tree round
                    // commits exactly the linear chain
                    let mut row_iter = rows_plan.into_iter().zip(vs).peekable();
                    for (ji, (id, tokens, _rows, parents)) in taken.into_iter().enumerate() {
                        let mut winner: Option<(Option<u8>, Option<Vec<i32>>, BackendVerdict)> =
                            None;
                        while row_iter.peek().map_or(false, |((rj, _, _), _)| *rj == ji) {
                            let ((_, leaf, path), v) = row_iter.next().expect("peeked row");
                            if winner.as_ref().map_or(true, |w| v.tau > w.2.tau) {
                                winner = Some((leaf, path, v));
                            }
                        }
                        let Some((leaf, path, v)) = winner else {
                            continue; // unreachable: every member planned >= 1 row
                        };
                        let applied = path.unwrap_or(tokens);
                        verdicts.push((id, applied, v, leaf, !parents.is_empty()));
                    }
                } else {
                    for (id, tokens, rows, _parents) in taken {
                        let s = &mut sessions[(id - 1) as usize];
                        let v = backend.verify_block(
                            id,
                            &s.core.committed,
                            &tokens,
                            &rows,
                            cfg.mode,
                            cfg.temperature,
                            cfg.top_p,
                            &mut s.rng,
                        )?;
                        total_tokens += tokens.len() + 1;
                        report.verify_rows += 1;
                        verdicts.push((id, tokens, v, None, false));
                    }
                }
                let t_batch = cloud_profile.t_base_ms
                    + total_tokens as f64 * cloud_profile.delta_per_token_ms;
                report.t_base_saved_ms +=
                    (members.len().saturating_sub(1)) as f64 * cloud_profile.t_base_ms;
                // one verify-latency sample per closed batch, keeping
                // `latency.verify_ms.count() == batches` in lockstep
                // with the serving metrics
                report.latency.verify_ms.record(t_batch);

                for (id, tokens, v, leaf, was_tree) in verdicts {
                    let s = &mut sessions[(id - 1) as usize];
                    let chan = s.channel.sample(now);
                    let vmsg = VerifyMsg {
                        session: id,
                        round: s.core.rounds as u32,
                        tau: v.tau as u8,
                        correction: v.correction,
                        eos: v.eos,
                        leaf,
                    };
                    let t_resp = now + t_batch + chan.prop_ms + chan.down_ms(vmsg.air_bytes());
                    let wait_ms = (now - s.arrived_ms).max(0.0);
                    report.latency.queue_ms.record(wait_ms);
                    report.latency.round_ms.record(wait_ms + t_batch);
                    report.latency.rtt_ms.record((t_resp - s.sent_ms).max(0.0));
                    if let Some(tr) = &cfg.trace {
                        // the serving stack's cloud-side window records
                        // (QueueWait/BucketPlan/VerifyBatch/Commit) plus
                        // the edge-side Downlink, same round number
                        let round = s.core.rounds as u32;
                        tr.record(id, round, SpanKind::QueueWait, wait_ms, 0, 0);
                        tr.record(id, round, SpanKind::BucketPlan, 0.0, batch as u32, bucket_k(max_k) as u32);
                        tr.record(id, round, SpanKind::VerifyBatch, t_batch, batch as u32, total_draft as u32);
                        tr.record(id, round, SpanKind::Downlink, t_resp - now, vmsg.air_bytes() as u32, 0);
                        tr.record(id, round, SpanKind::Commit, 0.0, v.tau as u32 + 1, 0);
                    }
                    if !tokens.is_empty() {
                        s.policy.observe(v.tau, tokens.len());
                    }
                    if was_tree {
                        report.tree_rounds += 1;
                        // per-row bookkeeping left the LAST row's
                        // acceptance as the session's length; re-assert
                        // the winning path's before reading capacity
                        // (`VerifierCore::close_window` does the same)
                        backend.note_committed(id, s.core.committed.len() + v.tau + 1);
                    }
                    let out_of_capacity = backend.remaining_capacity(id) <= cfg.capacity_floor;
                    let finished =
                        s.core
                            .apply_verdict(&tokens, v.tau, v.correction, v.eos, out_of_capacity);
                    report.rounds += 1;

                    // resolve the speculative next round (pipelined
                    // mode), mirroring PipelinedDrafter::resolve: it
                    // survives only on FULL acceptance with the bonus
                    // token predicted exactly, in a live session
                    let spec = s.spec_next.take();
                    let held = spec.as_ref().is_some_and(|sp| {
                        !finished && v.tau == tokens.len() && v.correction == sp.link_bonus
                    });

                    if finished {
                        if let Some(sp) = spec {
                            report.drafts_cancelled += 1;
                            report.draft_tokens_wasted += sp.tokens.len();
                        }
                        backend.end_session(id);
                        report.completed += 1;
                        report.tokens += s.core.new_tokens;
                        report.request_latency.add(t_resp - s.started_ms);
                        report
                            .per_token_latency
                            .add((t_resp - s.started_ms) / s.core.new_tokens.max(1) as f64);
                        if s.core.drafted > 0 {
                            report.acceptance.add(s.core.acceptance());
                        }
                        report.per_session.push(s.core.outcome());
                        report.wall_ms = report.wall_ms.max(t_resp);
                    } else if held {
                        let sp = spec.expect("held implies a speculative round");
                        debug_assert_eq!(sp.round, s.core.rounds as u32);
                        report.rounds_pipelined += 1;
                        s.sent_ms = sp.sent_ms;
                        // the cloud verifies the promoted round once it
                        // has BOTH arrived and seen this commit — the
                        // edge's draft + uplink legs are hidden
                        let ready = sp.arrive_ms.max(now + t_batch);
                        // the edge hears the verdict at t_resp and tops
                        // the pipe back up with the next speculation
                        if let Some(ob) = sp.own_bonus {
                            launch_spec(
                                s,
                                t_resp,
                                &sp.tokens,
                                ob,
                                sp.round + 1,
                                device,
                                cfg,
                                cloud_profile,
                            )?;
                        }
                        s.pending = Some((sp.tokens, sp.chosen_probs, sp.prob_rows, vec![]));
                        push(&mut heap, ready, Event::RequestArrives(id), &mut seq);
                    } else {
                        // broken prefix (or no speculation): retract and
                        // redraft from the true committed prefix
                        if let Some(sp) = spec {
                            report.drafts_cancelled += 1;
                            report.draft_tokens_wasted += sp.tokens.len();
                        }
                        let arrive = draft_and_send(s, t_resp, device, cfg, cloud_profile)?;
                        push(&mut heap, arrive, Event::RequestArrives(id), &mut seq);
                    }
                }
            }
        }
    }

    if report.batches > 0 {
        report.mean_batch /= report.batches as f64;
    }
    report.per_session.sort_by_key(|o| o.id);
    report.per_session_committed = report
        .per_session
        .iter()
        .map(|o| sessions[(o.id - 1) as usize].core.committed.clone())
        .collect();
    Ok(report)
}

/// The original PJRT entry point: every session drafts with the same
/// bundle (`draft_runtime`) and verifies on `cloud`'s deployed version.
#[allow(clippy::too_many_arguments)]
pub fn serve(
    cloud: &mut super::cloud::CloudEngine,
    draft_runtime: Rc<ModelRuntime>,
    prompts: &[Vec<i32>],
    device: &EdgeDevice,
    cloud_profile: &CloudProfile,
    net: &NetworkProfile,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut make_draft = |_id: u32| -> Result<Box<dyn DraftSource>> {
        Ok(Box::new(ModelDraft::new(draft_runtime.clone())?))
    };
    serve_with(
        cloud,
        &mut make_draft,
        prompts,
        device,
        cloud_profile,
        net,
        cfg,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::NetworkKind;
    use crate::devices::{A800_70B, JETSON_ORIN};
    use crate::runtime::{Engine, Manifest};
    use crate::serve::backend::{SyntheticDraft, SyntheticTarget};
    use super::super::cloud::CloudEngine;

    fn registry() -> Option<Registry> {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("manifest.json").exists() {
            return None;
        }
        let m = Manifest::load(&root).ok()?;
        if !m.weights.contains_key("draft_flex_llama2t") {
            return None;
        }
        Some(Registry::open(Rc::new(Engine::cpu().ok()?), Rc::new(m)))
    }

    fn prompts(n: usize) -> Vec<Vec<i32>> {
        (0..n)
            .map(|i| {
                let mut p = vec![1i32];
                for j in 0..6 {
                    p.push(64 + ((i * 7 + j * 13) % 64) as i32);
                }
                p
            })
            .collect()
    }

    #[test]
    fn serves_all_sessions_to_completion() {
        let Some(reg) = registry() else { return };
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let draft = reg.model("draft_flex_llama2t").unwrap();
        let net = NetworkProfile::new(NetworkKind::FourG);
        let cfg = ServeConfig {
            users: 4,
            max_new: 12,
            ..Default::default()
        };
        let rep = serve(
            &mut cloud,
            draft,
            &prompts(4),
            &JETSON_ORIN,
            &A800_70B,
            &net,
            &cfg,
        )
        .unwrap();
        assert_eq!(rep.completed, 4);
        assert!(rep.tokens >= 4 * 4, "tokens {}", rep.tokens);
        assert!(rep.throughput_tok_s() > 0.0);
        assert!(rep.request_latency.count() == 4);
        assert_eq!(rep.per_session.len(), 4);
        assert_eq!(
            rep.per_session.iter().map(|o| o.new_tokens).sum::<usize>(),
            rep.tokens
        );
    }

    #[test]
    fn batching_amortizes_t_base() {
        let Some(reg) = registry() else { return };
        let draft = reg.model("draft_flex_llama2t").unwrap();
        let net = NetworkProfile::new(NetworkKind::FiveG);

        // concurrent arrivals + wide window -> real batches
        let mut cloud = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let batched_cfg = ServeConfig {
            users: 6,
            max_new: 10,
            arrival_mean_ms: 1.0,
            window_ms: 50.0,
            ..Default::default()
        };
        let batched = serve(
            &mut cloud,
            draft.clone(),
            &prompts(6),
            &JETSON_ORIN,
            &A800_70B,
            &net,
            &batched_cfg,
        )
        .unwrap();
        assert!(batched.mean_batch > 1.2, "mean batch {}", batched.mean_batch);
        assert!(batched.t_base_saved_ms > 0.0);

        // tiny window -> batches of ~1
        let mut cloud2 = CloudEngine::new(&reg, "target_llama2t_base", 2).unwrap();
        let solo_cfg = ServeConfig {
            users: 6,
            max_new: 10,
            arrival_mean_ms: 1.0,
            window_ms: 0.01,
            ..Default::default()
        };
        let solo = serve(
            &mut cloud2,
            draft,
            &prompts(6),
            &JETSON_ORIN,
            &A800_70B,
            &net,
            &solo_cfg,
        )
        .unwrap();
        assert!(batched.mean_batch > solo.mean_batch);
        // amortization: real cloud time saved (throughput also improves
        // under load, but with 6 sessions the wait-window cost can mask
        // it — the saved T_base is the direct evidence)
        assert!(batched.t_base_saved_ms > solo.t_base_saved_ms);
    }

    #[test]
    fn synthetic_backend_serves_without_artifacts() {
        // serve_with needs no PJRT: the deterministic synthetic pair
        // drives the full scheduler (this test runs everywhere).
        let mut backend = SyntheticTarget::new(11);
        let mut make =
            |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(11))) };
        let net = NetworkProfile::new(NetworkKind::FourG);
        let cfg = ServeConfig {
            users: 4,
            max_new: 16,
            fixed_k: Some(4),
            seed: 5,
            ..Default::default()
        };
        let rep = serve_with(
            &mut backend,
            &mut make,
            &prompts(4),
            &JETSON_ORIN,
            &A800_70B,
            &net,
            &cfg,
        )
        .unwrap();
        assert_eq!(rep.completed, 4);
        // zero drift: every draft token accepted
        let acc: usize = rep.per_session.iter().map(|o| o.accepted).sum();
        let drafted: usize = rep.per_session.iter().map(|o| o.drafted).sum();
        assert_eq!(acc, drafted);
        assert!(rep.tokens >= 4 * 16);

        // bit-identical replay (NaN-safe deterministic event ordering)
        let mut backend2 = SyntheticTarget::new(11);
        let mut make2 =
            |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(11))) };
        let rep2 = serve_with(
            &mut backend2,
            &mut make2,
            &prompts(4),
            &JETSON_ORIN,
            &A800_70B,
            &net,
            &cfg,
        )
        .unwrap();
        assert_eq!(rep.per_session, rep2.per_session);
        assert_eq!(rep.batches, rep2.batches);
    }

    #[test]
    fn pipelined_sim_commits_identical_tokens_in_less_virtual_time() {
        let run = |depth: usize, drift: f64| {
            let mut backend = SyntheticTarget::new(11).with_version("evolved", drift);
            if drift > 0.0 {
                backend.deploy("evolved").unwrap();
            }
            let mut make = |_id: u32| -> Result<Box<dyn DraftSource>> {
                Ok(Box::new(SyntheticDraft::new(11)))
            };
            let net = NetworkProfile::new(NetworkKind::FourG);
            let cfg = ServeConfig {
                users: 4,
                max_new: 16,
                fixed_k: Some(4),
                seed: 5,
                pipeline_depth: depth,
                ..Default::default()
            };
            serve_with(
                &mut backend,
                &mut make,
                &prompts(4),
                &JETSON_ORIN,
                &A800_70B,
                &net,
                &cfg,
            )
            .unwrap()
        };

        // zero drift: every speculation holds — identical tokens,
        // strictly less virtual wall time (the RTT hiding)
        let seq = run(1, 0.0);
        let pipe = run(2, 0.0);
        assert_eq!(seq.per_session, pipe.per_session);
        assert_eq!(seq.per_session_committed, pipe.per_session_committed);
        assert_eq!(seq.rounds_pipelined, 0);
        assert!(pipe.rounds_pipelined > 0, "speculation must land");
        assert_eq!(pipe.drafts_cancelled, 0, "zero drift never cancels");
        assert!(
            pipe.wall_ms < seq.wall_ms,
            "pipelining must hide RTT: {} !< {}",
            pipe.wall_ms,
            seq.wall_ms
        );

        // drifted target: prefixes break, cancel-on-reject redrafts —
        // the committed sequences STILL match the sequential run exactly
        let seq_d = run(1, 0.3);
        let pipe_d = run(2, 0.3);
        assert_eq!(seq_d.per_session_committed, pipe_d.per_session_committed);
        assert_eq!(seq_d.per_session, pipe_d.per_session);
        assert!(pipe_d.drafts_cancelled > 0, "drift must break some prefixes");
        assert!(pipe_d.rounds_pipelined > 0, "some speculation must survive");
        assert!(pipe_d.draft_tokens_wasted > 0);
        // identical trajectories imply identical verified-round counts
        assert_eq!(pipe_d.rounds, seq_d.rounds);

        // bit-identical replay of the pipelined schedule itself
        let pipe2 = run(2, 0.3);
        assert_eq!(pipe_d.per_session, pipe2.per_session);
        assert_eq!(pipe_d.rounds_pipelined, pipe2.rounds_pipelined);
        assert_eq!(pipe_d.drafts_cancelled, pipe2.drafts_cancelled);
        assert_eq!(pipe_d.wall_ms, pipe2.wall_ms);
    }

    /// Fleet twin (`ServeConfig::fleet`): a deterministic mid-decode
    /// handoff schedule must move VIRTUAL TIME only — committed
    /// sequences and per-session counters stay byte-identical to the
    /// single-replica run, in sequential AND pipelined mode, and the
    /// whole schedule replays bit-identically.
    #[test]
    fn fleet_twin_redirects_move_time_not_tokens() {
        let run = |fleet: Option<FleetSimConfig>, depth: usize| {
            let mut backend = SyntheticTarget::new(11).with_version("evolved", 0.3);
            backend.deploy("evolved").unwrap();
            let mut make = |_id: u32| -> Result<Box<dyn DraftSource>> {
                Ok(Box::new(SyntheticDraft::new(11)))
            };
            let net = NetworkProfile::new(NetworkKind::FourG);
            let cfg = ServeConfig {
                users: 4,
                max_new: 16,
                fixed_k: Some(4),
                seed: 5,
                pipeline_depth: depth,
                fleet,
                ..Default::default()
            };
            serve_with(
                &mut backend,
                &mut make,
                &prompts(4),
                &JETSON_ORIN,
                &A800_70B,
                &net,
                &cfg,
            )
            .unwrap()
        };
        let fleet_cfg = || {
            Some(FleetSimConfig {
                replicas: 2,
                redirect_after_rounds: 2,
                max_redirects: 1,
                ..Default::default()
            })
        };
        for depth in [1usize, 2] {
            let single = run(None, depth);
            let fleet = run(fleet_cfg(), depth);
            assert_eq!(
                single.per_session_committed, fleet.per_session_committed,
                "depth {depth}: a handoff changed a committed token"
            );
            assert_eq!(single.per_session, fleet.per_session, "depth {depth}");
            assert!(
                fleet.sessions_redirected >= 1,
                "depth {depth}: the schedule must hand off at least one session"
            );
            assert!(
                fleet.wall_ms > single.wall_ms,
                "depth {depth}: a handoff must cost virtual time ({} !> {})",
                fleet.wall_ms,
                single.wall_ms
            );
            // bit-identical replay of the fleet schedule itself
            let fleet2 = run(fleet_cfg(), depth);
            assert_eq!(fleet.per_session, fleet2.per_session);
            assert_eq!(fleet.sessions_redirected, fleet2.sessions_redirected);
            assert_eq!(fleet.wall_ms, fleet2.wall_ms);
        }
    }

    /// Hetero twin (wire v8): an UNMETERED strong profile with
    /// branching 1 must reduce to the unprofiled v7 path exactly —
    /// same committed bytes, same counters, same virtual wall time —
    /// and the run must tally the session tiers.
    #[test]
    fn hetero_profiled_linear_matches_unprofiled() {
        use crate::device::{ComputeTier, DeviceProfile};
        let run = |profiles: Option<Vec<DeviceProfile>>| {
            let mut backend = SyntheticTarget::new(11).with_version("evolved", 0.3);
            backend.deploy("evolved").unwrap();
            let mut make = |_id: u32| -> Result<Box<dyn DraftSource>> {
                Ok(Box::new(SyntheticDraft::new(11)))
            };
            let net = NetworkProfile::new(NetworkKind::FourG);
            let cfg = ServeConfig {
                users: 4,
                max_new: 16,
                fixed_k: Some(4),
                seed: 5,
                profiles,
                ..Default::default()
            };
            serve_with(
                &mut backend,
                &mut make,
                &prompts(4),
                &JETSON_ORIN,
                &A800_70B,
                &net,
                &cfg,
            )
            .unwrap()
        };
        let plain = run(None);
        // the strong representative IS the fleet default device, so the
        // profiled run's virtual draft costs match too
        let strong = DeviceProfile::of(ComputeTier::Strong.representative());
        let profiled = run(Some(vec![strong]));
        assert_eq!(plain.per_session_committed, profiled.per_session_committed);
        assert_eq!(plain.per_session, profiled.per_session);
        assert_eq!(plain.wall_ms, profiled.wall_ms);
        assert_eq!(plain.verify_rows, profiled.verify_rows);
        assert_eq!(profiled.tree_rounds, 0, "branching 1 never drafts a tree");
        assert_eq!(plain.sessions_by_tier, [0, 0, 0]);
        assert_eq!(profiled.sessions_by_tier, [0, 0, 4]);
    }

    /// Hetero twin (wire v8): on a drifted target, a heterogeneous mix
    /// with branching 4 hedges bucket-aligned drift breaks and strictly
    /// increases accepted tokens per stacked dispatch over the same
    /// population drafting linearly — the sim side of the hetero bench
    /// gate. Weak sessions stay linear (tier caps), so rows fan out
    /// only where a tier can afford them.
    #[test]
    fn hetero_tree_twin_gains_accepted_per_dispatch() {
        use crate::device::{ComputeTier, DeviceProfile};
        let mix = || {
            Some(vec![
                DeviceProfile::of(ComputeTier::Weak.representative()),
                DeviceProfile::of(ComputeTier::Mid.representative()),
                DeviceProfile::of(ComputeTier::Strong.representative()),
                DeviceProfile::of(ComputeTier::Strong.representative()),
            ])
        };
        let run = |branching: usize| {
            let mut backend = SyntheticTarget::new(11).with_version("evolved", 0.3);
            backend.deploy("evolved").unwrap();
            let mut make = |_id: u32| -> Result<Box<dyn DraftSource>> {
                Ok(Box::new(SyntheticDraft::new(11)))
            };
            let net = NetworkProfile::new(NetworkKind::FourG);
            let cfg = ServeConfig {
                users: 12,
                max_new: 64,
                fixed_k: Some(4),
                seed: 5,
                profiles: mix(),
                branching,
                ..Default::default()
            };
            serve_with(
                &mut backend,
                &mut make,
                &prompts(12),
                &JETSON_ORIN,
                &A800_70B,
                &net,
                &cfg,
            )
            .unwrap()
        };
        let lin = run(1);
        let tre = run(4);
        assert_eq!(lin.completed, 12);
        assert_eq!(tre.completed, 12);
        assert_eq!(tre.sessions_by_tier, [3, 3, 6]);
        assert!(tre.tree_rounds > 0, "mid/strong sessions must draft trees");
        assert!(
            tre.verify_rows > tre.rounds,
            "tree rounds must fan out extra rows ({} rows / {} rounds)",
            tre.verify_rows,
            tre.rounds
        );
        assert_eq!(lin.verify_rows, lin.rounds);
        assert_eq!(lin.tree_rounds, 0);
        let acc = |r: &ServeReport| r.per_session.iter().map(|o| o.accepted).sum::<usize>();
        let (la, ta) = (acc(&lin), acc(&tre));
        assert!(
            ta * lin.stacked_dispatches > la * tre.stacked_dispatches,
            "tree speculation must raise accepted tokens per stacked dispatch: \
             {ta}/{} !> {la}/{}",
            tre.stacked_dispatches,
            lin.stacked_dispatches
        );
        // bit-identical replay of the tree schedule
        let tre2 = run(4);
        assert_eq!(tre.per_session, tre2.per_session);
        assert_eq!(tre.per_session_committed, tre2.per_session_committed);
        assert_eq!(tre.verify_rows, tre2.verify_rows);
        assert_eq!(tre.wall_ms, tre2.wall_ms);
    }

    #[test]
    fn scheduled_ordering_is_nan_safe() {
        // a poisoned event time must not panic the event loop's heap
        let a = Scheduled {
            at_ms: f64::NAN,
            seq: 1,
            ev: Event::BatchClose(0),
        };
        let b = Scheduled {
            at_ms: 1.0,
            seq: 2,
            ev: Event::BatchClose(0),
        };
        let mut heap = BinaryHeap::new();
        heap.push(Reverse(a));
        heap.push(Reverse(b));
        // total_cmp orders NaN after every real number
        assert_eq!(heap.pop().unwrap().0.at_ms, 1.0);
        assert!(heap.pop().unwrap().0.at_ms.is_nan());
    }
}
