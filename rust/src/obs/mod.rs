//! Observability: clocks, trace journals, latency histograms.
//!
//! This module is the shared instrumentation layer for the live
//! serving stack (`serve::*`) and the virtual-clock simulator
//! (`coordinator::scheduler`):
//!
//! * [`Clock`] / [`WallClock`] / [`VirtualClock`] — one time
//!   abstraction for both worlds ([`clock`]).
//! * [`Trace`] / [`SpanKind`] — bounded per-session event journals
//!   with JSONL export; the determinism contract extends to these:
//!   sim twin and serve must emit identical canonical event sequences
//!   ([`trace`]).
//! * [`LogHistogram`] — mergeable log-bucketed latency histograms
//!   ([`hist`]), grouped into the [`LatencySummary`] carried by
//!   `ServingMetrics`, `ServeReport`, and `EdgeReport`, and shipped
//!   over the wire in the v6 `StatsAck` frame.
//!
//! Everything here is optional at the call site (`Option<Trace>`
//! fields default to `None`); with observability disabled the serving
//! hot paths do no extra work.

pub mod clock;
pub mod hist;
pub mod trace;

pub use clock::{Clock, VirtualClock, WallClock};
pub use hist::{LogHistogram, HIST_BUCKETS, HIST_MIN_MS};
pub use trace::{SpanKind, Trace, TraceEvent, TRACE_RING_CAP};

use anyhow::Result;

use crate::util::json::Json;

/// The standard latency histogram bundle reported by the verifier, the
/// edge, the simulator, and (merged) the fleet registry. All four
/// histograms are mergeable across replicas.
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    /// End-to-end per-round latency (draft proposed → verdict applied).
    pub round_ms: LogHistogram,
    /// Admission-window wait (draft arrival → batch close).
    pub queue_ms: LogHistogram,
    /// Batched verification execution time per batch.
    pub verify_ms: LogHistogram,
    /// Edge-observed request→verdict round trip.
    pub rtt_ms: LogHistogram,
}

impl LatencySummary {
    pub fn new() -> LatencySummary {
        LatencySummary::default()
    }

    pub fn is_empty(&self) -> bool {
        self.round_ms.is_empty()
            && self.queue_ms.is_empty()
            && self.verify_ms.is_empty()
            && self.rtt_ms.is_empty()
    }

    /// Merge another summary in (fleet aggregation).
    pub fn merge(&mut self, other: &LatencySummary) {
        self.round_ms.merge(&other.round_ms);
        self.queue_ms.merge(&other.queue_ms);
        self.verify_ms.merge(&other.verify_ms);
        self.rtt_ms.merge(&other.rtt_ms);
    }

    /// Human-readable lines for the text reports (`render` paths);
    /// empty histograms are omitted, so pre-observability report text
    /// is unchanged when nothing was recorded.
    pub fn render_lines(&self, indent: &str) -> String {
        let mut out = String::new();
        for (name, h) in [
            ("round", &self.round_ms),
            ("queue", &self.queue_ms),
            ("verify", &self.verify_ms),
            ("rtt", &self.rtt_ms),
        ] {
            if !h.is_empty() {
                out.push_str(&format!("{indent}latency/{name}: {}\n", h.brief()));
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round_ms", self.round_ms.to_json()),
            ("queue_ms", self.queue_ms.to_json()),
            ("verify_ms", self.verify_ms.to_json()),
            ("rtt_ms", self.rtt_ms.to_json()),
        ])
    }

    /// Wire encoding: the four histograms back to back (sparse), used
    /// by the `StatsAck` payload.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        self.round_ms.encode_into(out);
        self.queue_ms.encode_into(out);
        self.verify_ms.encode_into(out);
        self.rtt_ms.encode_into(out);
    }

    /// Decode four histograms from the front of `b`; returns the
    /// summary and bytes consumed.
    pub fn decode_from(b: &[u8]) -> Result<(LatencySummary, usize)> {
        let mut pos = 0usize;
        let (round_ms, n) = LogHistogram::decode_from(&b[pos..])?;
        pos += n;
        let (queue_ms, n) = LogHistogram::decode_from(&b[pos..])?;
        pos += n;
        let (verify_ms, n) = LogHistogram::decode_from(&b[pos..])?;
        pos += n;
        let (rtt_ms, n) = LogHistogram::decode_from(&b[pos..])?;
        pos += n;
        Ok((
            LatencySummary {
                round_ms,
                queue_ms,
                verify_ms,
                rtt_ms,
            },
            pos,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_merge_and_roundtrip() {
        let mut a = LatencySummary::new();
        a.round_ms.record(12.0);
        a.queue_ms.record(0.5);
        a.verify_ms.record(3.0);
        let mut b = LatencySummary::new();
        b.round_ms.record(30.0);
        b.rtt_ms.record(9.0);
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.round_ms.count(), 2);
        assert_eq!(m.rtt_ms.count(), 1);

        let mut buf = Vec::new();
        m.encode_into(&mut buf);
        let (back, used) = LatencySummary::decode_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back.round_ms.count(), 2);
        assert_eq!(back.queue_ms.count(), 1);
        assert_eq!(back.verify_ms.count(), 1);
        assert_eq!(back.rtt_ms.count(), 1);
        assert_eq!(back.round_ms.p50(), m.round_ms.p50());

        let text = m.render_lines("  ");
        assert!(text.contains("latency/round"));
        assert!(!LatencySummary::new().render_lines("").contains("latency"));
    }
}
