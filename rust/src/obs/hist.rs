//! Log-bucketed latency histograms.
//!
//! Fixed geometric buckets (growth 2^(1/8) per bucket, ~±4.5% relative
//! error at the reported geometric midpoint) spanning 1 µs to ~1 hour —
//! wide enough for a queue wait and a whole fault-injected session
//! alike. Because the bucket boundaries are a pure function of the
//! bucket index, histograms from different replicas (or different
//! processes, via the wire encoding) MERGE exactly: the fleet's p99 is
//! computable without shipping raw samples, which a `Summary` (retained
//! samples) cannot do cheaply.
//!
//! The wire encoding is sparse — `(bucket index, count)` varint pairs —
//! so an idle replica's stats reply costs a handful of bytes.

use anyhow::{bail, Result};

/// Smallest distinguishable latency: everything at or below lands in
/// bucket 0.
pub const HIST_MIN_MS: f64 = 1e-3;
/// Buckets per octave (bucket width factor 2^(1/8) ≈ 1.09).
pub const HIST_BUCKETS_PER_OCTAVE: f64 = 8.0;
/// Total bucket count. Bucket 255's lower bound is ~1 hour; larger
/// values saturate there.
pub const HIST_BUCKETS: usize = 256;

/// A mergeable log-bucketed histogram of millisecond latencies.
///
/// `Default` is empty and allocation-free; the bucket array is
/// allocated on the first `record`, so carrying unused histograms in
/// metrics structs costs nothing.
#[derive(Debug, Clone, Default)]
pub struct LogHistogram {
    /// Bucket counts (empty until the first sample).
    counts: Vec<u64>,
    total: u64,
    sum_ms: f64,
    max_ms: f64,
}

/// Bucket index for a value (pure function — replicas agree by
/// construction).
fn bucket_of(ms: f64) -> usize {
    if !(ms > HIST_MIN_MS) {
        return 0; // includes NaN and negatives: never panic on bad input
    }
    let idx = ((ms / HIST_MIN_MS).log2() * HIST_BUCKETS_PER_OCTAVE).floor() as isize + 1;
    (idx.max(1) as usize).min(HIST_BUCKETS - 1)
}

/// Representative (geometric midpoint) value of a bucket.
fn bucket_value(idx: usize) -> f64 {
    if idx == 0 {
        HIST_MIN_MS
    } else {
        HIST_MIN_MS * ((idx as f64 - 0.5) / HIST_BUCKETS_PER_OCTAVE).exp2()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Record one latency sample (ms). NaN / negative values count into
    /// bucket 0 rather than poisoning the histogram.
    pub fn record(&mut self, ms: f64) {
        if self.counts.is_empty() {
            self.counts = vec![0; HIST_BUCKETS];
        }
        self.counts[bucket_of(ms)] += 1;
        self.total += 1;
        if ms.is_finite() && ms > 0.0 {
            self.sum_ms += ms;
            if ms > self.max_ms {
                self.max_ms = ms;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum_ms / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.max_ms
        }
    }

    /// Quantile estimate, `q` in [0, 1]: the geometric midpoint of the
    /// bucket holding the ceil(q·total)-th smallest sample. Relative
    /// error is bounded by the half-bucket width (~4.5%).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return bucket_value(i);
            }
        }
        bucket_value(HIST_BUCKETS - 1)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    /// Merge another histogram in (exact: buckets are index-aligned by
    /// construction). The fleet aggregation path.
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.total == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; HIST_BUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ms += other.sum_ms;
        if other.max_ms > self.max_ms {
            self.max_ms = other.max_ms;
        }
    }

    /// One-line rendering for reports: `n=…, p50/p90/p99/p999 in ms`.
    pub fn brief(&self) -> String {
        if self.total == 0 {
            return "n=0".to_string();
        }
        format!(
            "n={} p50 {:.2} ms, p90 {:.2} ms, p99 {:.2} ms, p999 {:.2} ms (max {:.2} ms)",
            self.total,
            self.p50(),
            self.p90(),
            self.p99(),
            self.p999(),
            self.max_ms,
        )
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let q = |v: f64| {
            if v.is_finite() {
                Json::Num(v)
            } else {
                Json::Null
            }
        };
        Json::obj(vec![
            ("count", Json::Num(self.total as f64)),
            ("mean_ms", q(self.mean())),
            ("p50_ms", q(self.p50())),
            ("p90_ms", q(self.p90())),
            ("p99_ms", q(self.p99())),
            ("p999_ms", q(self.p999())),
            ("max_ms", q(self.max())),
        ])
    }

    // -----------------------------------------------------------------
    // wire encoding (sparse): used by the v6 `StatsAck` frame
    // -----------------------------------------------------------------

    /// Append the sparse encoding: `varint(nonzero buckets)`, then
    /// `(varint index, varint count)` pairs in index order, then the
    /// `sum_ms`/`max_ms` f64 bits (LE).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let nonzero: Vec<(usize, u64)> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect();
        write_uv(out, nonzero.len() as u64);
        for (i, c) in nonzero {
            write_uv(out, i as u64);
            write_uv(out, c);
        }
        out.extend_from_slice(&self.sum_ms.to_le_bytes());
        out.extend_from_slice(&self.max_ms.to_le_bytes());
    }

    /// Decode one sparse encoding from the front of `b`; returns the
    /// histogram and the bytes consumed.
    pub fn decode_from(b: &[u8]) -> Result<(LogHistogram, usize)> {
        let mut pos = 0usize;
        let n = read_uv(b, &mut pos)?;
        if n as usize > HIST_BUCKETS {
            bail!("histogram claims {n} nonzero buckets (max {HIST_BUCKETS})");
        }
        let mut h = LogHistogram::new();
        let mut last: Option<u64> = None;
        for _ in 0..n {
            let idx = read_uv(b, &mut pos)?;
            if idx as usize >= HIST_BUCKETS {
                bail!("histogram bucket index {idx} out of range");
            }
            if last.is_some_and(|l| idx <= l) {
                bail!("histogram bucket indices must be strictly increasing");
            }
            last = Some(idx);
            let c = read_uv(b, &mut pos)?;
            if c == 0 {
                bail!("histogram encodes an empty bucket");
            }
            if h.counts.is_empty() {
                h.counts = vec![0; HIST_BUCKETS];
            }
            h.counts[idx as usize] = c;
            h.total = h
                .total
                .checked_add(c)
                .ok_or_else(|| anyhow::anyhow!("histogram count overflow"))?;
        }
        if pos + 16 > b.len() {
            bail!("histogram encoding truncated");
        }
        h.sum_ms = f64::from_le_bytes(b[pos..pos + 8].try_into().unwrap());
        h.max_ms = f64::from_le_bytes(b[pos + 8..pos + 16].try_into().unwrap());
        if !h.sum_ms.is_finite() || !h.max_ms.is_finite() {
            bail!("histogram sum/max not finite");
        }
        pos += 16;
        Ok((h, pos))
    }
}

// ---------------------------------------------------------------------
// LEB128 varints (self-contained: `obs` sits below `protocol`)
// ---------------------------------------------------------------------

pub(crate) fn write_uv(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

pub(crate) fn read_uv(b: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&byte) = b.get(*pos) else {
            bail!("varint truncated");
        };
        *pos += 1;
        if shift >= 64 {
            bail!("varint overlong");
        }
        v |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn quantiles_track_known_samples() {
        let mut h = LogHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64); // 1..1000 ms
        }
        assert_eq!(h.count(), 1000);
        assert!((h.p50() / 500.0 - 1.0).abs() < 0.05, "p50 {}", h.p50());
        assert!((h.p99() / 990.0 - 1.0).abs() < 0.05, "p99 {}", h.p99());
        assert!((h.mean() - 500.5).abs() < 1e-6);
        assert_eq!(h.max(), 1000.0);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert!(h.p50().is_nan() && h.mean().is_nan() && h.max().is_nan());
        let mut h = LogHistogram::new();
        h.record(f64::NAN);
        h.record(-5.0);
        h.record(0.0);
        assert_eq!(h.count(), 3);
        // all land in bucket 0 (the "at or below 1µs" bucket)
        assert_eq!(h.p50(), HIST_MIN_MS);
        // a saturating sample stays in range
        h.record(1e12);
        assert!(h.p999() > 1e6);
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut both = LogHistogram::new();
        for i in 0..200 {
            let x = 0.01 * 1.07f64.powi(i % 97);
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            both.record(x);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.count(), both.count());
        for q in [0.5, 0.9, 0.99, 0.999] {
            assert_eq!(merged.quantile(q), both.quantile(q), "q={q}");
        }
        assert!((merged.mean() - both.mean()).abs() < 1e-9);
        // merging into an empty histogram copies
        let mut empty = LogHistogram::new();
        empty.merge(&both);
        assert_eq!(empty.quantile(0.9), both.quantile(0.9));
    }

    #[test]
    fn wire_roundtrip_and_garbage_rejection() {
        let mut h = LogHistogram::new();
        for x in [0.004, 0.004, 1.5, 1.6, 250.0, 8000.0] {
            h.record(x);
        }
        let mut buf = Vec::new();
        h.encode_into(&mut buf);
        let (back, used) = LogHistogram::decode_from(&buf).unwrap();
        assert_eq!(used, buf.len());
        assert_eq!(back.count(), h.count());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(back.quantile(q), h.quantile(q));
        }
        assert_eq!(back.max(), h.max());
        // empty histogram round-trips too
        let mut buf2 = Vec::new();
        LogHistogram::new().encode_into(&mut buf2);
        let (e, _) = LogHistogram::decode_from(&buf2).unwrap();
        assert!(e.is_empty());
        // truncations never panic
        for cut in 0..buf.len() {
            assert!(LogHistogram::decode_from(&buf[..cut]).is_err(), "cut {cut}");
        }
        // out-of-range bucket index rejected
        let mut bad = Vec::new();
        write_uv(&mut bad, 1);
        write_uv(&mut bad, HIST_BUCKETS as u64);
        write_uv(&mut bad, 3);
        bad.extend_from_slice(&[0u8; 16]);
        assert!(LogHistogram::decode_from(&bad).is_err());
    }

    /// Satellite (CI matrix): quantile error bound vs an exact sort on
    /// random log-uniform samples — the half-bucket geometric-midpoint
    /// guarantee, checked at p50/p90/p99/p999.
    #[test]
    fn prop_quantile_error_bounds_vs_exact_sort() {
        prop::check(150, |rng| {
            let n = 1 + rng.next_range(400) as usize;
            let mut xs = Vec::with_capacity(n);
            let mut h = LogHistogram::new();
            for _ in 0..n {
                // log-uniform over [1e-2, 1e4] ms
                let x = 10f64.powf(rng.next_f64() * 6.0 - 2.0);
                xs.push(x);
                h.record(x);
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [50.0, 90.0, 99.0, 99.9] {
                let est = h.quantile(q / 100.0);
                // the estimate must sit within a bucket width of the
                // exact order statistics bracketing the rank
                let rank = q / 100.0 * (n - 1) as f64;
                let lo = xs[rank.floor() as usize];
                let hi = xs[(rank.ceil() as usize).min(n - 1)];
                prop::assert_prop(
                    est >= lo / 1.1 && est <= hi * 1.1,
                    format!("q{q}: estimate {est} outside [{lo}/1.1, {hi}*1.1] (n={n})"),
                )?;
            }
            prop::assert_prop(h.count() as usize == n, "count mismatch")
        });
    }
}
