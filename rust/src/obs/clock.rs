//! Time sources for the observability layer.
//!
//! Spans and latency histograms must work identically in the live
//! serving stack (real time) and the virtual-clock simulator
//! (`coordinator::scheduler`), so everything in `obs` reads time
//! through the [`Clock`] trait instead of touching `Instant` directly:
//!
//! * [`WallClock`] — monotonic wall time in ms since construction; the
//!   serving stack's default.
//! * [`VirtualClock`] — a shared, monotonically advanced virtual time;
//!   the simulator drives it from its event loop (`advance_to`), so a
//!   sim-side trace carries virtual timestamps and a `ScopeTimer`
//!   routed through it measures virtual elapsed time.
//!
//! Clocks are cheap to share (`Arc<dyn Clock>`) and lock-free.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotonic millisecond time source. `advance_to` is a no-op for
/// real clocks; virtual clocks ratchet forward through it.
pub trait Clock: Send + Sync {
    /// Current time in milliseconds (monotonic, origin arbitrary).
    fn now_ms(&self) -> f64;

    /// Advance a virtual clock to `ms` (monotonic: earlier times are
    /// ignored). Real clocks ignore this entirely.
    fn advance_to(&self, _ms: f64) {}
}

/// Monotonic wall time, in ms since the clock was created.
#[derive(Debug)]
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock {
            start: Instant::now(),
        }
    }

    /// A shared wall clock.
    pub fn shared() -> Arc<dyn Clock> {
        Arc::new(WallClock::new())
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Shared virtual time: reads return the last `advance_to` value.
/// Stored as f64 bits in an atomic so concurrent readers (e.g. a trace
/// shared between the sim loop and assertions) never lock. Time never
/// goes backwards — `advance_to` is a monotonic max.
#[derive(Debug, Default)]
pub struct VirtualClock {
    bits: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock {
            bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// A shared virtual clock starting at 0 ms.
    pub fn shared() -> Arc<VirtualClock> {
        Arc::new(VirtualClock::new())
    }
}

impl Clock for VirtualClock {
    fn now_ms(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Acquire))
    }

    fn advance_to(&self, ms: f64) {
        if !ms.is_finite() {
            return; // a poisoned event time must not wedge the clock
        }
        let _ = self
            .bits
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |cur| {
                (ms > f64::from_bits(cur)).then(|| ms.to_bits())
            });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_advances() {
        let c = WallClock::new();
        let a = c.now_ms();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(c.now_ms() > a);
        c.advance_to(1e9); // no-op on a real clock
        assert!(c.now_ms() < 1e6);
    }

    #[test]
    fn virtual_clock_is_monotonic() {
        let c = VirtualClock::new();
        assert_eq!(c.now_ms(), 0.0);
        c.advance_to(12.5);
        assert_eq!(c.now_ms(), 12.5);
        c.advance_to(3.0); // earlier: ignored
        assert_eq!(c.now_ms(), 12.5);
        c.advance_to(f64::NAN); // poisoned: ignored
        assert_eq!(c.now_ms(), 12.5);
        c.advance_to(40.0);
        assert_eq!(c.now_ms(), 40.0);
    }

    #[test]
    fn virtual_clock_shares_across_threads() {
        let c = VirtualClock::shared();
        let c2 = c.clone();
        std::thread::spawn(move || c2.advance_to(99.0))
            .join()
            .unwrap();
        assert_eq!(c.now_ms(), 99.0);
    }
}
