//! Per-session span/event journal.
//!
//! A [`Trace`] is an instance-scoped (NOT process-global — parallel
//! tests each own one), cheaply cloneable handle to a bounded
//! per-session event journal. The serving stack and the simulator both
//! record the same per-round lifecycle into it:
//!
//! `draft → uplink → queue_wait → bucket_plan → verify_batch →
//! downlink → commit`
//!
//! plus the fleet lifecycle events `export`, `redirect`, `import`,
//! `reroot`. Under the determinism contract the sim twin and the live
//! stack must produce the **same ordered event sequence** per session
//! (timestamps aside); [`Trace::sequence`] returns the canonical
//! ordering used by those pinned tests, which makes a trace diff the
//! first debugging tool for a determinism violation.
//!
//! Cost model: everything takes `&Option<Trace>`-shaped call sites —
//! when no trace is installed the instrumented code does no work at
//! all (a single `if let` on an `Option`), so the hot paths stay
//! within the microbench regression budget.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use super::clock::{Clock, WallClock};

/// Max retained events per session; older events are dropped (counted)
/// so a pathological session cannot grow the journal unboundedly.
pub const TRACE_RING_CAP: usize = 4096;

/// What happened. The numeric order is the canonical within-round
/// ordering used by [`Trace::sequence`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Edge drafted `a` tokens for a round.
    Draft = 0,
    /// Draft left the edge (first transmission only — Busy retries and
    /// replays do not re-record, so sim and serve agree).
    Uplink = 1,
    /// Draft waited `dur_ms` in the admission window before batching.
    QueueWait = 2,
    /// Batch planned: `a` = batch size, `b` = bucket K.
    BucketPlan = 3,
    /// Batched verification executed: `a` = batch size, `b` = total
    /// draft tokens in the batch.
    VerifyBatch = 4,
    /// Verdict left the cloud / arrived at the edge.
    Downlink = 5,
    /// Tokens committed: `a` = accepted count (+bonus).
    Commit = 6,
    /// Session exported to the fleet ledger.
    Export = 7,
    /// Session redirected toward another replica.
    Redirect = 8,
    /// Session imported from the fleet ledger.
    Import = 9,
    /// Edge rerooted its draft context after a handoff.
    Reroot = 10,
    /// Autoscale control action (journaled under the pseudo session
    /// `autoscale::CONTROL_SESSION`): `round` = control tick, `a` =
    /// action code (1 scale-up, 2 scale-down, 3 rebalance), `b` = the
    /// action's first argument (replicas added / victim id / source
    /// id).
    Autoscale = 11,
}

impl SpanKind {
    pub const ALL: [SpanKind; 12] = [
        SpanKind::Draft,
        SpanKind::Uplink,
        SpanKind::QueueWait,
        SpanKind::BucketPlan,
        SpanKind::VerifyBatch,
        SpanKind::Downlink,
        SpanKind::Commit,
        SpanKind::Export,
        SpanKind::Redirect,
        SpanKind::Import,
        SpanKind::Reroot,
        SpanKind::Autoscale,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Draft => "draft",
            SpanKind::Uplink => "uplink",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BucketPlan => "bucket_plan",
            SpanKind::VerifyBatch => "verify_batch",
            SpanKind::Downlink => "downlink",
            SpanKind::Commit => "commit",
            SpanKind::Export => "export",
            SpanKind::Redirect => "redirect",
            SpanKind::Import => "import",
            SpanKind::Reroot => "reroot",
            SpanKind::Autoscale => "autoscale",
        }
    }
}

/// One recorded event. `a`/`b` are kind-specific small arguments (see
/// [`SpanKind`] docs); unused ones are 0.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub session: u32,
    pub round: u32,
    pub kind: SpanKind,
    /// Clock reading when the event was recorded (wall or virtual ms).
    pub at_ms: f64,
    /// Duration of the spanned work, 0 for point events.
    pub dur_ms: f64,
    pub a: u32,
    pub b: u32,
}

#[derive(Debug, Default)]
struct SessionRing {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

#[derive(Debug, Default)]
struct Journal {
    sessions: BTreeMap<u32, SessionRing>,
    total: u64,
}

struct TraceInner {
    clock: Arc<dyn Clock>,
    journal: Mutex<Journal>,
}

/// Cloneable handle to a trace journal; see module docs.
#[derive(Clone)]
pub struct Trace {
    inner: Arc<TraceInner>,
}

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let j = self.inner.journal.lock().unwrap();
        f.debug_struct("Trace")
            .field("sessions", &j.sessions.len())
            .field("events", &j.total)
            .finish()
    }
}

impl Trace {
    /// A trace journal reading the given clock.
    pub fn new(clock: Arc<dyn Clock>) -> Trace {
        Trace {
            inner: Arc::new(TraceInner {
                clock,
                journal: Mutex::new(Journal::default()),
            }),
        }
    }

    /// A trace on a fresh wall clock — the serving-stack default.
    pub fn wall() -> Trace {
        Trace::new(WallClock::shared())
    }

    /// The clock this trace reads. The simulator drives its virtual
    /// clock through this handle (`trace.clock().advance_to(now)`).
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.inner.clock
    }

    /// Current clock reading, for span begin/end bracketing at call
    /// sites that want a duration without allocating a guard.
    pub fn now_ms(&self) -> f64 {
        self.inner.clock.now_ms()
    }

    /// Record one event (timestamped from the trace clock).
    pub fn record(&self, session: u32, round: u32, kind: SpanKind, dur_ms: f64, a: u32, b: u32) {
        let at_ms = self.inner.clock.now_ms();
        let mut j = self.inner.journal.lock().unwrap();
        let ring = j.sessions.entry(session).or_default();
        if ring.events.len() >= TRACE_RING_CAP {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(TraceEvent {
            session,
            round,
            kind,
            at_ms,
            dur_ms,
            a,
            b,
        });
        j.total += 1;
    }

    /// Point-event shorthand (no duration, no args).
    pub fn event(&self, session: u32, round: u32, kind: SpanKind) {
        self.record(session, round, kind, 0.0, 0, 0);
    }

    /// Total events recorded (including any since dropped from rings).
    pub fn len(&self) -> u64 {
        self.inner.journal.lock().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Session ids present in the journal, ascending.
    pub fn sessions(&self) -> Vec<u32> {
        let j = self.inner.journal.lock().unwrap();
        j.sessions.keys().copied().collect()
    }

    /// Events dropped from a session's ring (0 when within cap).
    pub fn dropped(&self, session: u32) -> u64 {
        let j = self.inner.journal.lock().unwrap();
        j.sessions.get(&session).map_or(0, |r| r.dropped)
    }

    /// Raw events for a session in insertion order.
    pub fn events(&self, session: u32) -> Vec<TraceEvent> {
        let j = self.inner.journal.lock().unwrap();
        j.sessions
            .get(&session)
            .map_or_else(Vec::new, |r| r.events.iter().cloned().collect())
    }

    /// The canonical ordered event sequence for a session: events
    /// sorted by `(round, kind)` with insertion order as tiebreak.
    ///
    /// This is the determinism-contract view: the serving stack records
    /// concurrently (edge task vs verifier task), so raw insertion
    /// order interleaves nondeterministically ACROSS kinds — but sorted
    /// by `(round, kind)`, equality of two sequences reduces to
    /// equality of per-`(round, kind)` event counts, which the contract
    /// pins. Timestamps and durations are deliberately excluded.
    pub fn sequence(&self, session: u32) -> Vec<(u32, SpanKind)> {
        let mut evs: Vec<(u32, SpanKind)> = self
            .events(session)
            .iter()
            .map(|e| (e.round, e.kind))
            .collect();
        evs.sort(); // stable: insertion order breaks (round, kind) ties
        evs
    }

    /// Count events of one kind for a session.
    pub fn count(&self, session: u32, kind: SpanKind) -> usize {
        self.events(session).iter().filter(|e| e.kind == kind).count()
    }

    /// Serialize the whole journal as JSONL, one event per line,
    /// sessions ascending, insertion order within a session.
    pub fn to_jsonl(&self) -> String {
        use crate::util::json::Json;
        let j = self.inner.journal.lock().unwrap();
        let mut out = String::new();
        for ring in j.sessions.values() {
            for e in &ring.events {
                let line = Json::obj(vec![
                    ("session", Json::Num(e.session as f64)),
                    ("round", Json::Num(e.round as f64)),
                    ("kind", Json::str(e.kind.name())),
                    ("at_ms", Json::Num(e.at_ms)),
                    ("dur_ms", Json::Num(e.dur_ms)),
                    ("a", Json::Num(e.a as f64)),
                    ("b", Json::Num(e.b as f64)),
                ]);
                out.push_str(&line.to_string());
                out.push('\n');
            }
        }
        out
    }

    /// Write the JSONL journal to a file (the `--trace PATH` flag).
    pub fn write_jsonl(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::VirtualClock;

    #[test]
    fn records_and_orders_events() {
        let t = Trace::wall();
        t.record(1, 0, SpanKind::Draft, 0.0, 4, 0);
        t.event(1, 0, SpanKind::Uplink);
        t.record(1, 0, SpanKind::Commit, 0.0, 3, 0);
        t.record(1, 1, SpanKind::Draft, 0.0, 4, 0);
        // commit recorded "late" for round 0 after round 1's draft —
        // canonical sequence still orders by round first
        t.record(1, 0, SpanKind::Downlink, 0.0, 0, 0);
        assert_eq!(
            t.sequence(1),
            vec![
                (0, SpanKind::Draft),
                (0, SpanKind::Uplink),
                (0, SpanKind::Downlink),
                (0, SpanKind::Commit),
                (1, SpanKind::Draft),
            ]
        );
        assert_eq!(t.count(1, SpanKind::Draft), 2);
        assert_eq!(t.len(), 5);
        assert!(t.sequence(7).is_empty());
        assert_eq!(t.sessions(), vec![1]);
    }

    #[test]
    fn virtual_clock_timestamps() {
        let vc = VirtualClock::shared();
        let t = Trace::new(vc.clone());
        vc.advance_to(10.0);
        t.event(1, 0, SpanKind::Draft);
        vc.advance_to(25.0);
        t.event(1, 0, SpanKind::Commit);
        let evs = t.events(1);
        assert_eq!(evs[0].at_ms, 10.0);
        assert_eq!(evs[1].at_ms, 25.0);
    }

    #[test]
    fn ring_is_bounded() {
        let t = Trace::wall();
        for r in 0..(TRACE_RING_CAP as u32 + 10) {
            t.event(3, r, SpanKind::Draft);
        }
        assert_eq!(t.events(3).len(), TRACE_RING_CAP);
        assert_eq!(t.dropped(3), 10);
        assert_eq!(t.len(), TRACE_RING_CAP as u64 + 10);
        // oldest were dropped: first retained round is 10
        assert_eq!(t.events(3)[0].round, 10);
    }

    #[test]
    fn jsonl_export_parses() {
        let t = Trace::wall();
        t.record(2, 0, SpanKind::VerifyBatch, 1.25, 3, 12);
        let out = t.to_jsonl();
        assert_eq!(out.lines().count(), 1);
        let v = crate::util::json::Json::parse(out.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("kind").and_then(|k| k.as_str()), Some("verify_batch"));
        assert_eq!(v.get("a").and_then(|a| a.as_f64()), Some(3.0));
    }
}
