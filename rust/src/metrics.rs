//! Request-level metrics aggregation (DESIGN.md S15): collects
//! `RequestResult`s into per-method summaries with the paper's metrics —
//! ms/token, ETGR, acceptance, per-round latency decomposition, energy
//! breakdown and byte accounting.

use crate::coordinator::pipeline::RequestResult;
use crate::energy::EnergyBreakdown;
use crate::obs::LatencySummary;
use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::Table;
use std::collections::BTreeMap;

/// Aggregate over many requests of one method/configuration.
#[derive(Debug, Default, Clone)]
pub struct MethodMetrics {
    pub method: String,
    pub requests: usize,
    pub tokens: usize,
    pub rounds: usize,
    pub ms_per_token: Summary,
    pub request_ms: Summary,
    pub prefill_ms: Summary,
    pub acceptance: Summary,
    pub k_used: Summary,
    pub round_edge_ms: Summary,
    pub round_up_ms: Summary,
    pub round_cloud_ms: Summary,
    pub round_down_ms: Summary,
    pub bytes_up: usize,
    pub bytes_down: usize,
    pub energy: EnergyBreakdown,
    pub fade_rounds: usize,
}

impl MethodMetrics {
    pub fn new(method: impl Into<String>) -> MethodMetrics {
        MethodMetrics {
            method: method.into(),
            ..Default::default()
        }
    }

    pub fn record(&mut self, r: &RequestResult) {
        self.requests += 1;
        self.tokens += r.new_tokens;
        self.rounds += r.rounds;
        self.ms_per_token.add(r.ms_per_token());
        self.request_ms.add(r.prefill_ms + r.decode_ms);
        self.prefill_ms.add(r.prefill_ms);
        if r.drafted > 0 {
            self.acceptance.add(r.acceptance_rate());
        }
        self.bytes_up += r.bytes_up;
        self.bytes_down += r.bytes_down;
        self.energy.add(&r.energy);
        for l in &r.rounds_log {
            self.k_used.add(l.k as f64);
            self.round_edge_ms.add(l.t_edge_ms);
            self.round_up_ms.add(l.t_up_ms);
            self.round_cloud_ms.add(l.t_cloud_ms);
            self.round_down_ms.add(l.t_down_ms);
            self.fade_rounds += l.fading as usize;
        }
    }

    /// Effective token generation rate, tokens/s of virtual time (eq. 2).
    pub fn etgr(&self) -> f64 {
        1e3 / self.ms_per_token.mean()
    }

    pub fn energy_per_token(&self) -> f64 {
        self.energy.total_j() / self.tokens.max(1) as f64
    }

    pub fn bytes_up_per_token(&self) -> f64 {
        self.bytes_up as f64 / self.tokens.max(1) as f64
    }
}

/// A labeled collection of method metrics (one experiment cell group).
#[derive(Debug, Default)]
pub struct MetricsSet {
    pub by_method: BTreeMap<String, MethodMetrics>,
}

impl MetricsSet {
    pub fn record(&mut self, r: &RequestResult) {
        self.by_method
            .entry(r.method.clone())
            .or_insert_with(|| MethodMetrics::new(r.method.clone()))
            .record(r);
    }

    /// Render the standard comparison table (the per-figure row format).
    pub fn table(&self, title: &str, baseline: Option<&str>) -> Table {
        let base_ms = baseline
            .and_then(|b| self.by_method.get(b))
            .map(|m| m.ms_per_token.mean());
        let mut t = Table::new(
            title,
            &["Method", "ms/tok", "p95", "speedup", "ETGR tok/s", "accept", "mean K", "kB up/tok", "J/tok"],
        );
        for m in self.by_method.values() {
            let ms = m.ms_per_token.mean();
            t.row(vec![
                m.method.clone(),
                format!("{ms:.1}"),
                format!("{:.1}", m.ms_per_token.p95()),
                base_ms.map(|b| format!("{:.2}x", b / ms)).unwrap_or_default(),
                format!("{:.2}", m.etgr()),
                format!("{:.2}", m.acceptance.mean()),
                format!("{:.1}", m.k_used.mean()),
                format!("{:.2}", m.bytes_up_per_token() / 1e3),
                format!("{:.2}", m.energy_per_token()),
            ]);
        }
        t
    }
}

/// Per-session serving counters for the `serve` subsystem (the live
/// TCP/loopback server — as opposed to `MethodMetrics`, which aggregates
/// virtual-clock experiment results). One instance lives in the
/// verification service and is snapshotted by `stats`/`shutdown`.
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    /// KV sessions created by `Open` (nonce-deduplicated retransmits
    /// reattach instead of counting again).
    pub sessions_opened: usize,
    /// Sessions that decoded to completion (EOS, budget, or capacity).
    pub sessions_completed: usize,
    /// Sessions ended by an explicit client Bye before completion.
    pub sessions_aborted: usize,
    /// Sessions whose connection died: kept alive for the resume grace
    /// window instead of being dropped.
    pub sessions_parked: usize,
    /// Successful reconnect-and-resume handshakes (includes resumes of
    /// just-finished sessions fetching their final tail).
    pub sessions_resumed: usize,
    /// Parked sessions reclaimed because no resume arrived in time.
    pub sessions_evicted: usize,
    /// Drafts answered from the per-session verdict cache (transport
    /// duplicates and post-resume retransmits).
    pub verdicts_replayed: usize,
    /// Connections turned away at the wire-version gate.
    pub handshakes_rejected: usize,
    /// Fleet handoffs OUT (wire v5): sessions exported to the shared
    /// ledger and answered with a `Redirect` — a drain or targeted
    /// rebalance shedding load to a sibling replica.
    pub sessions_redirected: usize,
    /// Fleet handoffs IN: sessions reconstructed from the shared
    /// ledger on a `Resume` (exported by a sibling — or by this
    /// replica, when the edge could not follow the redirect).
    pub sessions_imported: usize,
    /// Rounds verified from a SPECULATIVE draft whose optimistic basis
    /// matched the committed prefix exactly (wire v3 pipelining) — each
    /// one is an edge round trip hidden behind the previous verify.
    pub rounds_pipelined: usize,
    /// Speculative drafts discarded: retracted by an edge `Cancel`,
    /// failed the basis check after a partial acceptance, or voided by
    /// their session finishing underneath them.
    pub drafts_cancelled: usize,
    /// Draft tokens of discarded speculative rounds — uplink air spent
    /// on speculation that did not land.
    pub draft_tokens_wasted: usize,
    /// Pending drafts skipped at window close because their session
    /// detached (or was torn down) mid-window. Counted so these drafts
    /// never vanish without a trace.
    pub drafts_orphaned: usize,
    /// Drafts turned away with a `Busy` deferral because the pending-
    /// draft queue was at its admission bound (wire v4). Each is one
    /// edge retry; committed tokens never change.
    pub drafts_busy: usize,
    /// Finished-session residues reclaimed by the periodic sweep after
    /// their resume-grace window expired.
    pub residues_expired: usize,
    /// Fleet-ledger entries aged out by this replica's TTL sweep
    /// (`SessionLedger::expire_before`): exported sessions whose
    /// exporter died before its grace-window reap fired and whose edge
    /// never resumed. In-tree every expired entry was once somebody's
    /// `sessions_redirected`; the conservation audit checks the
    /// per-replica common case (a replica predominantly collects its
    /// own abandoned exports — cross-replica collection would need a
    /// fleet-level rollup, noted as headroom in `docs/AUTOSCALE.md`).
    pub ledger_expired: usize,
    /// Verified rounds across sessions.
    pub rounds: usize,
    /// Verification batches closed (each one `verify_batch` call).
    pub batches: usize,
    /// Stacked `[B, K]` device dispatches across all closed batches:
    /// one per distinct planner bucket class per close (ragged drafts
    /// pad to power-of-two K and share one stacked forward per class).
    /// Conservation: each batch stacks at least one bucket and at most
    /// one per member row, so `batches <= stacked_dispatches <= rounds`.
    pub stacked_dispatches: usize,
    /// Ragged verification rows across all closed batches: one per
    /// root→leaf path of each verified draft. Equal to `rounds` when
    /// every draft is a linear chain; larger under wire v8 tree
    /// speculation, where one round fans out into `n_leaves` rows that
    /// ride the same stacked dispatch classes as the main chain.
    pub verify_rows: usize,
    /// Rounds whose draft carried a tree tail (wire v8): the round
    /// expanded into multiple rows and committed the best root path.
    pub tree_rounds: usize,
    /// Verify requests per closed batch.
    pub batch_occupancy: Summary,
    /// Continuous batching only (`BatchMode::Continuous`): executor
    /// ROWS verified at each rolling close — how full the stacked
    /// executor ran without a window timer to fill it. A tree draft's
    /// leaves each occupy one row, so this can exceed the slot count
    /// under wire v8 tree speculation. Empty in windowed mode.
    pub slot_occupancy: Summary,
    /// Pending-draft backlog observed at each window close (the
    /// admission queue's operating depth).
    pub queue_depth: Summary,
    /// Committed tokens (accepted + correction/bonus) across sessions.
    pub tokens_committed: usize,
    /// Draft tokens verified across sessions.
    pub drafted: usize,
    /// Draft tokens accepted across sessions.
    pub accepted: usize,
    /// Target version hot-swaps performed while serving.
    pub hot_swaps: usize,
    /// Protocol-level air bytes (header + payload accounting).
    pub bytes_up: usize,
    pub bytes_down: usize,
    /// Per completed session: acceptance rate and round count.
    pub session_acceptance: Summary,
    pub session_rounds: Summary,
    /// Every draft submitted to the verifier, before any disposition.
    /// Conservation (see [`ServingMetrics::invariant_violations`]):
    /// every received draft ends up verified, cancelled, orphaned,
    /// busy-deferred, replayed from cache, or swallowed.
    pub drafts_received: usize,
    /// Drafts quietly dropped without an edge-visible verdict (e.g. a
    /// speculative draft whose session vanished before promotion).
    pub drafts_swallowed: usize,
    /// Fleet imports that found the ledger entry already finished and
    /// answered done immediately (no live session created).
    pub sessions_imported_done: usize,
    /// Sessions opened carrying a wire v8 device profile, by compute
    /// tier (weak / mid / strong). Profile-less opens (pre-v8 peers,
    /// fleet imports) count in none of the cells, so the sum is
    /// bounded by `sessions_opened`.
    pub sessions_by_device_tier: [usize; 3],
    /// Latency histograms (p50/p90/p99/p999); empty unless the verifier
    /// records rounds. Mergeable across replicas.
    pub latency: LatencySummary,
}

impl ServingMetrics {
    /// Record one verified round of one session.
    pub fn note_round(&mut self, drafted: usize, tau: usize) {
        self.rounds += 1;
        self.drafted += drafted;
        self.accepted += tau;
        self.tokens_committed += tau + 1;
    }

    pub fn note_batch(&mut self, occupancy: usize) {
        self.batches += 1;
        self.batch_occupancy.add(occupancy as f64);
    }

    pub fn finish_session(&mut self, core: &crate::serve::session::SessionCore) {
        self.sessions_completed += 1;
        self.session_rounds.add(core.rounds as f64);
        if core.drafted > 0 {
            self.session_acceptance.add(core.acceptance());
        }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_occupancy.mean()
    }

    /// Conservation audit: every opened session and every received
    /// draft must be accounted for by exactly one disposition counter.
    /// `sessions_live` and `drafts_pending` describe state still in
    /// flight (0 at a clean shutdown). Returns one message per violated
    /// invariant; empty means the books balance.
    pub fn invariant_violations(&self, sessions_live: usize, drafts_pending: usize) -> Vec<String> {
        let mut v = Vec::new();
        let opened = self.sessions_opened + self.sessions_imported;
        let disposed = self.sessions_completed
            + self.sessions_aborted
            + self.sessions_evicted
            + self.sessions_redirected
            + self.sessions_imported_done
            + sessions_live;
        if opened != disposed {
            v.push(format!(
                "session conservation: opened {} + imported {} != \
                 completed {} + aborted {} + evicted {} + redirected {} \
                 + imported-done {} + live {}",
                self.sessions_opened,
                self.sessions_imported,
                self.sessions_completed,
                self.sessions_aborted,
                self.sessions_evicted,
                self.sessions_redirected,
                self.sessions_imported_done,
                sessions_live,
            ));
        }
        let drafts_disposed = self.rounds
            + self.drafts_cancelled
            + self.drafts_orphaned
            + self.drafts_busy
            + self.verdicts_replayed
            + self.drafts_swallowed
            + drafts_pending;
        if self.drafts_received != drafts_disposed {
            v.push(format!(
                "draft conservation: received {} != verified {} + cancelled {} \
                 + orphaned {} + busy {} + replayed {} + swallowed {} + pending {}",
                self.drafts_received,
                self.rounds,
                self.drafts_cancelled,
                self.drafts_orphaned,
                self.drafts_busy,
                self.verdicts_replayed,
                self.drafts_swallowed,
                drafts_pending,
            ));
        }
        if self.accepted > self.drafted {
            v.push(format!(
                "acceptance: accepted {} > drafted {}",
                self.accepted, self.drafted
            ));
        }
        // each verified round commits tau + 1 tokens: rounds ≤ committed ≤ accepted + rounds
        if self.tokens_committed < self.rounds || self.tokens_committed > self.accepted + self.rounds
        {
            v.push(format!(
                "token conservation: committed {} outside [rounds {}, accepted {} + rounds]",
                self.tokens_committed, self.rounds, self.accepted
            ));
        }
        // stacked-dispatch conservation (see the field docs): every
        // closed batch costs at least one stacked [B, K] dispatch and
        // never more than one per verified ROW (tree drafts fan one
        // round into several rows, so the row ledger is the bound —
        // `rounds` covers verifiers that predate row tracking)
        let rows = self.verify_rows.max(self.rounds);
        if self.stacked_dispatches < self.batches || self.stacked_dispatches > rows {
            v.push(format!(
                "stacked dispatch conservation: {} dispatches outside \
                 [batches {}, rows {}]",
                self.stacked_dispatches, self.batches, rows
            ));
        }
        // every verified round contributes at least one row once rows
        // are tracked, and only tree rounds contribute more than one
        if self.verify_rows != 0 && self.verify_rows < self.rounds {
            v.push(format!(
                "row conservation: {} rows < {} rounds",
                self.verify_rows, self.rounds
            ));
        }
        if self.tree_rounds > self.rounds {
            v.push(format!(
                "row conservation: {} tree rounds > {} rounds",
                self.tree_rounds, self.rounds
            ));
        }
        if self.verify_rows != 0 && self.verify_rows > self.rounds && self.tree_rounds == 0 {
            v.push(format!(
                "row conservation: {} rows > {} rounds with no tree round",
                self.verify_rows, self.rounds
            ));
        }
        // continuous-mode closes record occupancy once per batch
        if self.slot_occupancy.count() > self.batches {
            v.push(format!(
                "slot occupancy conservation: {} samples > {} batches",
                self.slot_occupancy.count(),
                self.batches
            ));
        }
        if self.latency.verify_ms.count() != self.batches as u64 {
            v.push(format!(
                "histogram conservation: verify_ms count {} != batches {}",
                self.latency.verify_ms.count(),
                self.batches
            ));
        }
        // every TTL-expired ledger entry was once an export; a replica
        // sweeping its own orphans can never expire more than it
        // redirected (see the `ledger_expired` field docs for the
        // cross-replica caveat)
        if self.ledger_expired > self.sessions_redirected {
            v.push(format!(
                "ledger conservation: expired {} > redirected {}",
                self.ledger_expired, self.sessions_redirected
            ));
        }
        // a device-tier cell is only ever filled by a profiled Open
        let profiled: usize = self.sessions_by_device_tier.iter().sum();
        if profiled > self.sessions_opened {
            v.push(format!(
                "device tier conservation: {} profiled sessions > {} opened",
                profiled, self.sessions_opened
            ));
        }
        v
    }

    /// `debug_assert`-backed conservation audit, called at shutdown.
    /// Release builds log violations instead of aborting.
    pub fn check_invariants(&self, sessions_live: usize, drafts_pending: usize) {
        let violations = self.invariant_violations(sessions_live, drafts_pending);
        for msg in &violations {
            crate::util::log::log(
                crate::util::log::Level::Warn,
                "metrics",
                &format!("invariant violated: {msg}"),
            );
        }
        debug_assert!(
            violations.is_empty(),
            "ServingMetrics conservation audit failed:\n  {}",
            violations.join("\n  ")
        );
    }

    /// JSON snapshot for `--metrics-json PATH` and `BENCH_serve.json`.
    pub fn to_json(&self) -> Json {
        let n = |v: usize| Json::Num(v as f64);
        Json::obj(vec![
            ("sessions_opened", n(self.sessions_opened)),
            ("sessions_completed", n(self.sessions_completed)),
            ("sessions_aborted", n(self.sessions_aborted)),
            ("sessions_parked", n(self.sessions_parked)),
            ("sessions_resumed", n(self.sessions_resumed)),
            ("sessions_evicted", n(self.sessions_evicted)),
            ("sessions_redirected", n(self.sessions_redirected)),
            ("sessions_imported", n(self.sessions_imported)),
            ("sessions_imported_done", n(self.sessions_imported_done)),
            ("sessions_weak", n(self.sessions_by_device_tier[0])),
            ("sessions_mid", n(self.sessions_by_device_tier[1])),
            ("sessions_strong", n(self.sessions_by_device_tier[2])),
            ("ledger_expired", n(self.ledger_expired)),
            ("handshakes_rejected", n(self.handshakes_rejected)),
            ("verdicts_replayed", n(self.verdicts_replayed)),
            ("residues_expired", n(self.residues_expired)),
            ("rounds", n(self.rounds)),
            ("rounds_pipelined", n(self.rounds_pipelined)),
            ("batches", n(self.batches)),
            ("mean_batch", Json::Num(self.mean_batch())),
            ("stacked_dispatches", n(self.stacked_dispatches)),
            ("verify_rows", n(self.verify_rows)),
            ("tree_rounds", n(self.tree_rounds)),
            (
                "slot_occupancy_mean",
                Json::Num(if self.slot_occupancy.count() == 0 {
                    0.0
                } else {
                    self.slot_occupancy.mean()
                }),
            ),
            ("slot_occupancy_samples", n(self.slot_occupancy.count())),
            ("drafts_received", n(self.drafts_received)),
            ("drafts_cancelled", n(self.drafts_cancelled)),
            ("drafts_orphaned", n(self.drafts_orphaned)),
            ("drafts_busy", n(self.drafts_busy)),
            ("drafts_swallowed", n(self.drafts_swallowed)),
            ("draft_tokens_wasted", n(self.draft_tokens_wasted)),
            ("tokens_committed", n(self.tokens_committed)),
            ("drafted", n(self.drafted)),
            ("accepted", n(self.accepted)),
            ("acceptance_rate", Json::Num(self.acceptance_rate())),
            ("hot_swaps", n(self.hot_swaps)),
            ("bytes_up", n(self.bytes_up)),
            ("bytes_down", n(self.bytes_down)),
            ("latency", self.latency.to_json()),
        ])
    }

    /// Human-readable multi-line report for CLIs and examples.
    pub fn render(&self, title: &str) -> String {
        let mut s = format!(
            "{title}\n\
             \x20 sessions         {} completed / {} opened ({} aborted, {} handshakes rejected)\n\
             \x20 resume           {} parked, {} resumed, {} evicted, {} verdicts replayed, {} residues expired\n\
             \x20 fleet            {} redirected out, {} imported, {} ledger entries expired\n\
             \x20 pipeline         {} rounds pipelined, {} drafts cancelled, {} draft tokens wasted\n\
             \x20 rounds           {} in {} batches (mean occupancy {:.2}, {} stacked dispatches, {} rows, {} tree rounds)\n\
             \x20 admission        {} busy deferrals, {} drafts orphaned, queue depth mean {:.2} / p95 {:.0}\n\
             \x20 tokens           {} committed, acceptance {:.3} ({} / {} drafted)\n\
             \x20 hot-swaps        {}\n\
             \x20 air bytes        {} up / {} down",
            self.sessions_completed,
            self.sessions_opened,
            self.sessions_aborted,
            self.handshakes_rejected,
            self.sessions_parked,
            self.sessions_resumed,
            self.sessions_evicted,
            self.verdicts_replayed,
            self.residues_expired,
            self.sessions_redirected,
            self.sessions_imported,
            self.ledger_expired,
            self.rounds_pipelined,
            self.drafts_cancelled,
            self.draft_tokens_wasted,
            self.rounds,
            self.batches,
            self.mean_batch(),
            self.stacked_dispatches,
            self.verify_rows,
            self.tree_rounds,
            self.drafts_busy,
            self.drafts_orphaned,
            self.queue_depth.mean(),
            self.queue_depth.p95(),
            self.tokens_committed,
            self.acceptance_rate(),
            self.accepted,
            self.drafted,
            self.hot_swaps,
            self.bytes_up,
            self.bytes_down,
        );
        let latency = self.latency.render_lines("  ");
        if !latency.is_empty() {
            s.push('\n');
            s.push_str(latency.trim_end());
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::RoundLog;

    fn fake_result(method: &str, ms: f64, tokens: usize) -> RequestResult {
        RequestResult {
            method: method.into(),
            prompt_tokens: 10,
            new_tokens: tokens,
            rounds: 2,
            prefill_ms: 50.0,
            decode_ms: ms * tokens as f64,
            bytes_up: 1000,
            bytes_down: 200,
            drafted: 8,
            accepted: 5,
            rounds_pipelined: 0,
            drafts_cancelled: 0,
            draft_tokens_wasted: 0,
            energy: Default::default(),
            output: vec![1; tokens],
            rounds_log: vec![
                RoundLog {
                    k: 4,
                    tau: 3,
                    committed: 4,
                    t_step_ms: 100.0,
                    t_edge_ms: 10.0,
                    t_up_ms: 20.0,
                    t_cloud_ms: 60.0,
                    t_down_ms: 10.0,
                    bytes_up: 500,
                    bytes_down: 100,
                    fading: false,
                },
                RoundLog {
                    k: 4,
                    tau: 2,
                    committed: 3,
                    t_step_ms: 120.0,
                    t_edge_ms: 10.0,
                    t_up_ms: 40.0,
                    t_cloud_ms: 60.0,
                    t_down_ms: 10.0,
                    bytes_up: 500,
                    bytes_down: 100,
                    fading: true,
                },
            ],
        }
    }

    #[test]
    fn aggregates_and_speedups() {
        let mut set = MetricsSet::default();
        for _ in 0..3 {
            set.record(&fake_result("Cloud-Only", 100.0, 10));
            set.record(&fake_result("FlexSpec", 50.0, 10));
        }
        let co = &set.by_method["Cloud-Only"];
        let fs = &set.by_method["FlexSpec"];
        assert_eq!(co.requests, 3);
        assert_eq!(co.tokens, 30);
        assert!((fs.etgr() - 20.0).abs() < 1e-9);
        assert!((fs.acceptance.mean() - 5.0 / 8.0).abs() < 1e-9);
        assert_eq!(fs.fade_rounds, 3);
        let t = set.table("demo", Some("Cloud-Only"));
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("2.00x"));
    }

    #[test]
    fn serving_metrics_aggregate() {
        let mut m = ServingMetrics::default();
        m.sessions_opened = 2;
        m.note_batch(2);
        m.note_round(4, 3);
        m.note_round(4, 1);
        let mut core = crate::serve::session::SessionCore::new(1, &[1, 2], 8);
        core.apply_verdict(&[9, 9, 9, 9], 3, 7, false, false);
        m.finish_session(&core);
        assert_eq!(m.rounds, 2);
        assert_eq!(m.tokens_committed, 6);
        assert!((m.acceptance_rate() - 0.5).abs() < 1e-12);
        assert!((m.mean_batch() - 2.0).abs() < 1e-12);
        assert_eq!(m.sessions_completed, 1);
        m.sessions_parked = 2;
        m.sessions_resumed = 1;
        m.sessions_evicted = 1;
        m.verdicts_replayed = 3;
        m.residues_expired = 1;
        m.rounds_pipelined = 4;
        m.drafts_cancelled = 2;
        m.draft_tokens_wasted = 8;
        m.drafts_busy = 5;
        m.drafts_orphaned = 1;
        m.sessions_redirected = 3;
        m.sessions_imported = 2;
        m.ledger_expired = 1;
        m.queue_depth.add(2.0);
        let r = m.render("serving");
        assert!(r.contains("6 committed"));
        assert!(r.contains("hot-swaps"));
        assert!(r.contains("2 parked, 1 resumed, 1 evicted, 3 verdicts replayed, 1 residues expired"));
        assert!(r.contains("3 redirected out, 2 imported, 1 ledger entries expired"));
        assert!(r.contains("4 rounds pipelined, 2 drafts cancelled, 8 draft tokens wasted"));
        assert!(r.contains("5 busy deferrals, 1 drafts orphaned"));
    }

    /// A metrics state where all conservation books balance.
    fn balanced() -> ServingMetrics {
        let mut m = ServingMetrics::default();
        m.sessions_opened = 4;
        m.sessions_imported = 1;
        m.sessions_completed = 3;
        m.sessions_aborted = 1;
        m.sessions_redirected = 1;
        m.drafts_received = 10;
        m.rounds = 5;
        m.drafts_cancelled = 2;
        m.drafts_orphaned = 1;
        m.drafts_busy = 1;
        m.verdicts_replayed = 1;
        m.drafted = 20;
        m.accepted = 15;
        m.tokens_committed = 20; // accepted + one bonus per round
        m.batches = 3;
        m.stacked_dispatches = 4; // within [batches, rows]
        m.verify_rows = 6; // 5 linear rows + one tree round's extra row
        m.tree_rounds = 1;
        for _ in 0..3 {
            m.latency.verify_ms.record(1.0);
        }
        m
    }

    #[test]
    fn invariants_hold_on_balanced_books() {
        assert!(balanced().invariant_violations(0, 0).is_empty());
        // in-flight state balances too
        let mut m = balanced();
        m.sessions_opened += 2; // two still live
        m.drafts_received += 1; // one still pending
        assert!(m.invariant_violations(2, 1).is_empty());
        m.check_invariants(2, 1); // must not panic
    }

    #[test]
    fn invariant_session_conservation() {
        let mut m = balanced();
        m.sessions_opened += 1; // one session vanished without a disposition
        let v = m.invariant_violations(0, 0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("session conservation"));
    }

    #[test]
    fn invariant_draft_conservation() {
        let mut m = balanced();
        m.drafts_received += 1; // a draft vanished without a disposition
        let v = m.invariant_violations(0, 0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("draft conservation"));
    }

    #[test]
    fn invariant_acceptance_bound() {
        let mut m = balanced();
        m.accepted = m.drafted + 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("acceptance:")), "{v:?}");
    }

    #[test]
    fn invariant_token_conservation() {
        let mut m = balanced();
        m.tokens_committed = m.accepted + m.rounds + 1; // more than tau+1 per round
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("token conservation")), "{v:?}");
        let mut m = balanced();
        m.tokens_committed = m.rounds - 1; // a round committed nothing
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("token conservation")), "{v:?}");
    }

    #[test]
    fn invariant_stacked_dispatch_bounds() {
        // fewer dispatches than batches: a batch ran without stacking
        let mut m = balanced();
        m.stacked_dispatches = m.batches - 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("stacked dispatch")), "{v:?}");
        // more dispatches than rows: stacking fragmented past 1/row
        let mut m = balanced();
        m.stacked_dispatches = m.verify_rows + 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("stacked dispatch")), "{v:?}");
        // the boundary values balance
        let mut m = balanced();
        m.stacked_dispatches = m.batches;
        assert!(m.invariant_violations(0, 0).is_empty());
        m.stacked_dispatches = m.verify_rows;
        assert!(m.invariant_violations(0, 0).is_empty());
        // a pre-row-tracking verifier (verify_rows == 0) still bounds
        // dispatches by rounds
        let mut m = balanced();
        m.verify_rows = 0;
        m.tree_rounds = 0;
        m.stacked_dispatches = m.rounds;
        assert!(m.invariant_violations(0, 0).is_empty());
        m.stacked_dispatches = m.rounds + 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("stacked dispatch")), "{v:?}");
    }

    #[test]
    fn invariant_row_conservation() {
        // fewer rows than rounds: a verified round left no row behind
        let mut m = balanced();
        m.verify_rows = m.rounds - 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("row conservation")), "{v:?}");
        // extra rows demand a tree round to explain them
        let mut m = balanced();
        m.tree_rounds = 0;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("no tree round")), "{v:?}");
        // tree rounds are a subset of rounds
        let mut m = balanced();
        m.tree_rounds = m.rounds + 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("tree rounds")), "{v:?}");
        // all-linear books (rows == rounds, no tree rounds) balance
        let mut m = balanced();
        m.verify_rows = m.rounds;
        m.tree_rounds = 0;
        assert!(m.invariant_violations(0, 0).is_empty());
    }

    #[test]
    fn invariant_slot_occupancy_samples() {
        // continuous closes record occupancy at most once per batch
        let mut m = balanced();
        for _ in 0..m.batches {
            m.slot_occupancy.add(2.0);
        }
        assert!(m.invariant_violations(0, 0).is_empty());
        m.slot_occupancy.add(2.0);
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("slot occupancy")), "{v:?}");
    }

    #[test]
    fn invariant_histogram_totals() {
        let mut m = balanced();
        m.batches += 1; // a batch closed without a verify_ms sample
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("histogram conservation")), "{v:?}");
    }

    #[test]
    fn invariant_ledger_expiry_bound() {
        // expiring exactly what was redirected balances...
        let mut m = balanced();
        m.ledger_expired = m.sessions_redirected;
        assert!(m.invariant_violations(0, 0).is_empty());
        // ...expiring MORE than this replica ever exported cannot
        m.ledger_expired = m.sessions_redirected + 1;
        let v = m.invariant_violations(0, 0);
        assert!(v.iter().any(|s| s.contains("ledger conservation")), "{v:?}");
    }

    #[test]
    #[should_panic(expected = "conservation audit failed")]
    #[cfg(debug_assertions)]
    fn check_invariants_asserts_in_debug() {
        let mut m = balanced();
        m.drafts_received += 5;
        m.check_invariants(0, 0);
    }

    #[test]
    fn metrics_json_snapshot() {
        let mut m = balanced();
        m.ledger_expired = 1;
        let j = m.to_json();
        assert_eq!(j.get("rounds").and_then(|x| x.as_usize()), Some(5));
        assert_eq!(j.get("stacked_dispatches").and_then(|x| x.as_usize()), Some(4));
        assert_eq!(j.get("slot_occupancy_samples").and_then(|x| x.as_usize()), Some(0));
        assert_eq!(j.get("drafts_received").and_then(|x| x.as_usize()), Some(10));
        assert_eq!(j.get("ledger_expired").and_then(|x| x.as_usize()), Some(1));
        assert!(j.get("latency").and_then(|l| l.get("verify_ms")).is_some());
        // render appends latency lines once histograms fill
        assert!(m.render("t").contains("latency/verify"));
        assert!(!ServingMetrics::default().render("t").contains("latency/"));
    }

    #[test]
    fn round_decomposition_sums() {
        let mut m = MethodMetrics::new("x");
        m.record(&fake_result("x", 80.0, 7));
        let total = m.round_edge_ms.mean() + m.round_up_ms.mean()
            + m.round_cloud_ms.mean() + m.round_down_ms.mean();
        assert!((total - 110.0).abs() < 1e-9); // mean of 100 and 120
    }
}
