//! FlexSpec — frozen drafts meet evolving targets in edge-cloud
//! collaborative LLM speculative decoding (reproduction).
//!
//! Three-layer architecture (DESIGN.md):
//!   L3 (this crate): the coordinator — edge/cloud engines, channel-aware
//!       adaptive speculation, wireless simulation, baselines, experiments.
//!   L2/L1 (python/, build-time only): JAX transformer family + Pallas
//!       kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! The request path is pure rust: `runtime` loads the AOT artifacts via
//! PJRT and everything above it is deterministic simulation + real model
//! execution.

pub mod channel;
pub mod coordinator;
pub mod devices;
pub mod energy;
pub mod protocol;
pub mod runtime;
pub mod util;

pub mod metrics;
pub mod workload;
pub mod baselines;
pub mod experiments;
pub mod report;

mod cli_entry;
pub use cli_entry::cli_main;
