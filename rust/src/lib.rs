//! FlexSpec — frozen drafts meet evolving targets in edge-cloud
//! collaborative LLM speculative decoding (reproduction).
//!
//! Three-layer architecture (DESIGN.md):
//!   L3 (this crate): the coordinator — edge/cloud engines, channel-aware
//!       adaptive speculation, wireless simulation, baselines, experiments.
//!   L2/L1 (python/, build-time only): JAX transformer family + Pallas
//!       kernels, AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! The request path is pure rust: `runtime` loads the AOT artifacts via
//! PJRT and everything above it is deterministic simulation + real model
//! execution.
//!
//! On top of the simulator sits the SERVING subsystem (`serve`): a
//! tokio-based cloud verification server and edge client running the
//! same wire protocol (`protocol::{DraftMsg, VerifyMsg}`) over real TCP
//! with a length-prefixed frame codec and a wire-format version
//! handshake (`protocol::frame`). Its `Transport` trait has two
//! implementations — `TcpTransport` (real sockets) and
//! `LoopbackTransport` (in-process pair, optionally metered through the
//! deterministic wireless-channel simulation) — and the cloud side runs
//! a session manager with per-connection KV sessions, a cross-connection
//! dynamic verification batcher (the same `serve::session::BatchWindow`
//! state machine the simulator uses), LoRA/target-version hot-swap
//! without dropping sessions, and graceful shutdown. With the
//! deterministic synthetic backend and a fixed stride, loopback serving
//! reproduces the simulator's token counts exactly — experiments stay
//! reproducible while the transport is real.

pub mod autoscale;
pub mod channel;
pub mod coordinator;
pub mod device;
pub mod devices;
pub mod energy;
pub mod load;
pub mod obs;
pub mod protocol;
pub mod runtime;
pub mod serve;
pub mod util;

pub mod metrics;
pub mod workload;
pub mod baselines;
pub mod experiments;
pub mod report;

mod cli_entry;
pub use cli_entry::cli_main;
