//! Workload layer: the six evaluation datasets as synthetic grammar
//! generators, PRNG-matched with the python training corpora.

pub mod corpus;
pub mod generator;

pub use corpus::{Domain, Style, BOS, EOS, PAD};
pub use generator::{RequestSpec, WorkloadGen};
