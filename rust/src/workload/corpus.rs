//! Rust mirror of `python/compile/corpus.py` (DESIGN.md S14).
//!
//! CROSS-LANGUAGE CONTRACT: the same splitmix64 PRNG and the same grammar
//! tables as the python training pipeline, so serving-time prompts come
//! from exactly the distribution the models were trained/fine-tuned on.
//! Golden sequences are pinned in both test suites; additionally the
//! domain tables are validated against `manifest.json` at load time.

use crate::runtime::{DomainInfo, Manifest};
use crate::util::rng::SplitMix64;
use anyhow::{bail, Result};

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const COMMON_OFFSET: u64 = 448;
pub const COMMON_SIZE: u64 = 64;

/// Grammar style (mirrors python's BASE / EVOLVED / FOREIGN).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Style {
    Base,
    Evolved,
    Foreign,
}

/// One task grammar (constants mirror python; validated vs manifest).
#[derive(Debug, Clone)]
pub struct Domain {
    pub name: &'static str,
    pub offset: u64,
    pub size: u64,
    pub mult: u64,
    pub inc: u64,
    pub p_det: f64,
    pub p_eos: f64,
    pub prompt_len: (u64, u64),
    pub gen_len: (u64, u64),
    pub evolved_mult: u64,
    pub evolved_inc: u64,
    pub evolve_mod: u64,
}

pub const DOMAINS: &[Domain] = &[
    Domain { name: "general",   offset: 16,  size: 48, mult: 5,  inc: 11, p_det: 0.75, p_eos: 0.020, prompt_len: (8, 24),   gen_len: (24, 64),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
    Domain { name: "gsm8k",     offset: 64,  size: 64, mult: 7,  inc: 3,  p_det: 0.85, p_eos: 0.015, prompt_len: (12, 32),  gen_len: (32, 96),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
    Domain { name: "humaneval", offset: 128, size: 64, mult: 11, inc: 5,  p_det: 0.85, p_eos: 0.012, prompt_len: (10, 28),  gen_len: (40, 112), evolved_mult: 0, evolved_inc: 0, evolve_mod: 3 },
    Domain { name: "mtbench",   offset: 192, size: 64, mult: 3,  inc: 17, p_det: 0.78, p_eos: 0.018, prompt_len: (8, 40),   gen_len: (32, 96),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
    Domain { name: "nq",        offset: 256, size: 64, mult: 13, inc: 7,  p_det: 0.80, p_eos: 0.030, prompt_len: (6, 20),   gen_len: (16, 48),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
    Domain { name: "nq_rag",    offset: 256, size: 64, mult: 13, inc: 7,  p_det: 0.80, p_eos: 0.025, prompt_len: (48, 120), gen_len: (24, 64),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
    Domain { name: "wmt14",     offset: 320, size: 64, mult: 9,  inc: 13, p_det: 0.80, p_eos: 0.020, prompt_len: (12, 36),  gen_len: (24, 72),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
    Domain { name: "cnndm",     offset: 384, size: 64, mult: 5,  inc: 19, p_det: 0.80, p_eos: 0.022, prompt_len: (64, 160), gen_len: (24, 80),  evolved_mult: 0, evolved_inc: 0, evolve_mod: 4 },
];

pub fn domain(name: &str) -> Result<&'static Domain> {
    DOMAINS
        .iter()
        .find(|d| d.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown domain '{name}'"))
}

/// Multiplicative hash picking pseudorandom token subsets (mirrors
/// python `subset_hash`; see that docstring for why not residue classes).
pub fn subset_hash(cur: u64, salt: u64) -> u64 {
    ((cur.wrapping_mul(2654435761).wrapping_add(salt.wrapping_mul(40503))) & 0xFFFF_FFFF) >> 13
}

impl Domain {
    /// Deterministic rule under a style — mirrors python `rule_next`.
    pub fn rule_next(&self, cur: u64, style: Style) -> u64 {
        match style {
            Style::Evolved if subset_hash(cur, self.offset) % self.evolve_mod == self.evolve_mod - 1 => {
                let m = if self.evolved_mult != 0 { self.evolved_mult } else { self.mult + 2 };
                let c = if self.evolved_inc != 0 { self.evolved_inc } else { self.inc + 5 };
                self.offset + ((cur * m + c) % self.size)
            }
            Style::Foreign
                if (self.name == "general" && subset_hash(cur, 77) % 4 == 0)
                    || (self.name != "general" && subset_hash(cur, 77) % 2 == 1) =>
            {
                self.offset + ((cur * (self.mult + 4) + self.inc + 7) % self.size)
            }
            _ => self.offset + ((cur * self.mult + self.inc) % self.size),
        }
    }

    /// One grammar step — mirrors python `next_token`.
    pub fn next_token(&self, cur: u64, rng: &mut SplitMix64, style: Style) -> u64 {
        if rng.next_f64() < self.p_det {
            self.rule_next(cur, style)
        } else if rng.next_f64() < 0.5 {
            self.offset + rng.next_range(self.size)
        } else {
            COMMON_OFFSET + rng.next_range(COMMON_SIZE)
        }
    }

    /// `length` grammar tokens — mirrors python `gen_tokens`.
    pub fn gen_tokens(&self, rng: &mut SplitMix64, length: usize, style: Style) -> Vec<i32> {
        let mut cur = self.offset + rng.next_range(self.size);
        let mut out = Vec::with_capacity(length);
        for _ in 0..length {
            out.push(cur as i32);
            cur = self.next_token(cur, rng, style);
        }
        out
    }

    /// BOS + prompt prefix — mirrors python `gen_prompt`.
    pub fn gen_prompt(&self, rng: &mut SplitMix64) -> Vec<i32> {
        let (lo, hi) = self.prompt_len;
        let n = lo + rng.next_range(hi - lo);
        let mut p = vec![BOS];
        p.extend(self.gen_tokens(rng, n as usize, Style::Base));
        p
    }

    /// Output-length budget for a request of this task shape.
    pub fn gen_budget(&self, rng: &mut SplitMix64) -> usize {
        let (lo, hi) = self.gen_len;
        (lo + rng.next_range(hi - lo)) as usize
    }

    /// Validate against the manifest's domain table (wire-format guard).
    pub fn validate(&self, info: &DomainInfo) -> Result<()> {
        if self.offset != info.offset
            || self.size != info.size
            || self.mult != info.mult
            || self.inc != info.inc
            || (self.p_det - info.p_det).abs() > 1e-9
            || self.prompt_len != info.prompt_len
            || self.gen_len != info.gen_len
            || self.evolve_mod != info.evolve_mod
        {
            bail!(
                "domain '{}' diverges between rust tables and manifest — \
                 regenerate artifacts or update workload/corpus.rs",
                self.name
            );
        }
        Ok(())
    }
}

/// Validate every domain against the manifest (call at startup).
pub fn validate_against_manifest(m: &Manifest) -> Result<()> {
    for d in DOMAINS {
        if let Some(info) = m.domains.get(d.name) {
            d.validate(info)?;
        } else {
            bail!("manifest is missing domain '{}'", d.name);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_gsm8k_sequence_matches_python() {
        // python/tests/test_corpus.py::test_gen_tokens_golden pins this.
        let mut rng = SplitMix64::new(42);
        let d = domain("gsm8k").unwrap();
        let toks = d.gen_tokens(&mut rng, 12, Style::Base);
        assert_eq!(toks, vec![85, 86, 93, 78, 101, 100, 127, 124, 103, 84, 79, 108]);
    }

    #[test]
    fn tokens_stay_in_vocab_ranges() {
        for d in DOMAINS {
            let mut rng = SplitMix64::new(7);
            for t in d.gen_tokens(&mut rng, 256, Style::Evolved) {
                let t = t as u64;
                let in_domain = t >= d.offset && t < d.offset + d.size;
                let in_common = (COMMON_OFFSET..COMMON_OFFSET + COMMON_SIZE).contains(&t);
                assert!(in_domain || in_common, "{} produced {t}", d.name);
            }
        }
    }

    #[test]
    fn evolved_rewrites_only_hashed_subset() {
        let d = domain("gsm8k").unwrap();
        let mut changed = 0;
        for cur in d.offset..d.offset + d.size {
            let base = d.rule_next(cur, Style::Base);
            let evo = d.rule_next(cur, Style::Evolved);
            if subset_hash(cur, d.offset) % d.evolve_mod != d.evolve_mod - 1 {
                assert_eq!(base, evo);
            } else {
                changed += (base != evo) as usize;
            }
        }
        // roughly 1/evolve_mod of the transitions rewritten
        assert!((8..=26).contains(&changed), "changed {changed}");
    }

    #[test]
    fn prompt_shapes_follow_task() {
        let mut rng = SplitMix64::new(9);
        let rag = domain("nq_rag").unwrap();
        let nq = domain("nq").unwrap();
        let p_rag = rag.gen_prompt(&mut rng);
        let p_nq = nq.gen_prompt(&mut rng);
        assert!(p_rag.len() > p_nq.len(), "RAG prompts are long");
        assert_eq!(p_rag[0], BOS);
    }

    #[test]
    fn validates_against_real_manifest_if_present() {
        let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if root.join("manifest.json").exists() {
            let m = Manifest::load(&root).unwrap();
            validate_against_manifest(&m).unwrap();
        }
    }
}
