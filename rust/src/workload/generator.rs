//! Request workload generation: streams of (prompt, output budget)
//! requests per dataset, deterministic per seed.

use super::corpus::{domain, Domain};
use crate::util::rng::SplitMix64;
use anyhow::Result;

/// One request of a workload.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    pub id: u64,
    pub domain: &'static str,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Deterministic request stream for one dataset.
pub struct WorkloadGen {
    dom: &'static Domain,
    rng: SplitMix64,
    next_id: u64,
    /// Cap prompt + generation to the model context (max_seq) minus slack.
    pub context_budget: usize,
}

impl WorkloadGen {
    pub fn new(dataset: &str, seed: u64) -> Result<WorkloadGen> {
        Ok(WorkloadGen {
            dom: domain(dataset)?,
            rng: SplitMix64::new(seed ^ 0x0517_AD10),
            next_id: 0,
            context_budget: 244, // max_seq 256 - block slack
        })
    }

    pub fn domain_name(&self) -> &'static str {
        self.dom.name
    }

    /// Next request. Prompt + budget always fit the context window.
    pub fn next_request(&mut self) -> RequestSpec {
        self.next_id += 1;
        let mut prompt = self.dom.gen_prompt(&mut self.rng);
        let mut budget = self.dom.gen_budget(&mut self.rng);
        if prompt.len() + budget > self.context_budget {
            // RAG/summarization prompts can be long: trim output first,
            // then the prompt head (keep the BOS + recent context).
            budget = budget.min(self.context_budget.saturating_sub(prompt.len()).max(16));
            if prompt.len() + budget > self.context_budget {
                let keep = self.context_budget - budget;
                let tail_start = prompt.len() - (keep - 1);
                let mut trimmed = vec![super::corpus::BOS];
                trimmed.extend_from_slice(&prompt[tail_start..]);
                prompt = trimmed;
            }
        }
        RequestSpec {
            id: self.next_id,
            domain: self.dom.name,
            prompt,
            max_new: budget,
        }
    }

    /// A batch of requests.
    pub fn take(&mut self, n: usize) -> Vec<RequestSpec> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// All six evaluation datasets of the paper (Tables III/IV).
pub const EVAL_DATASETS: &[(&str, &str)] = &[
    ("gsm8k", "GSM8K (Math)"),
    ("nq", "Natural Questions (QA)"),
    ("nq_rag", "Natural Questions (RAG)"),
    ("mtbench", "MT-Bench (Chat)"),
    ("wmt14", "WMT14 (Trans)"),
    ("cnndm", "CNN/DM (Summ)"),
];

/// Which evolved cloud version serves a dataset (nq_rag reuses nq's).
pub fn target_for_dataset(family: &str, dataset: &str) -> String {
    let dom = if dataset == "nq_rag" { "nq" } else { dataset };
    format!("lora_{family}_{dom}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_fit_context() {
        for (ds, _) in EVAL_DATASETS {
            let mut g = WorkloadGen::new(ds, 3).unwrap();
            for r in g.take(50) {
                assert!(r.prompt.len() + r.max_new <= 244, "{ds}");
                assert_eq!(r.prompt[0], super::super::corpus::BOS);
                assert!(r.max_new >= 16);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadGen::new("gsm8k", 5).unwrap().take(5);
        let b = WorkloadGen::new("gsm8k", 5).unwrap().take(5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
        }
        let c = WorkloadGen::new("gsm8k", 6).unwrap().take(5);
        assert!(a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn dataset_target_mapping() {
        assert_eq!(target_for_dataset("llama2t", "gsm8k"), "lora_llama2t_gsm8k");
        assert_eq!(target_for_dataset("llama2t", "nq_rag"), "lora_llama2t_nq");
    }

    #[test]
    fn rag_prompts_longer_than_qa() {
        let mut rag = WorkloadGen::new("nq_rag", 1).unwrap();
        let mut nq = WorkloadGen::new("nq", 1).unwrap();
        let lr: usize = rag.take(20).iter().map(|r| r.prompt.len()).sum();
        let ln: usize = nq.take(20).iter().map(|r| r.prompt.len()).sum();
        assert!(lr > 2 * ln);
    }
}
