//! The seven methods of the paper's evaluation, as pipeline configs
//! (DESIGN.md S13). Every method runs through the SAME `Pipeline` /
//! `CloudEngine` / channel simulation so only the documented differences
//! remain:
//!
//! | method      | draft source                | stride policy      | sync |
//! |-------------|-----------------------------|--------------------|------|
//! | Cloud-Only  | none                        | K = 0              | no   |
//! | Std. SD     | generic draft (unaligned)   | fixed K = 5        | no*  |
//! | PLD         | prompt n-gram lookup        | fixed K = 5        | no   |
//! | Lookahead   | context n-gram pool         | fixed K = 5        | no   |
//! | Medusa-1    | per-version synced draft    | fixed K = 3 heads  | YES  |
//! | EAGLE-2     | per-version synced draft    | fixed K = 6        | YES  |
//! | DSSD        | aligned draft               | class heuristic    | no   |
//! | FlexSpec    | frozen anchor-aligned draft | channel-aware K*   | no   |
//!
//! (*) Std. SD keeps its stale generic draft — that IS the paper's
//! "performance collapse" condition. Medusa/EAGLE-2 are "(Ideal Synced)":
//! their drafts were re-distilled against the deployed target version in
//! the offline pipeline, and the sync traffic they would ship is priced
//! by `coordinator::sync`. DSSD gets the aligned draft but only a
//! network-class stride heuristic, isolating the paper's channel-aware
//! contribution (see DESIGN.md).

use crate::channel::NetworkKind;
use crate::protocol::WireFormat;
use crate::coordinator::edge::{DraftSource, ModelDraft, NoDraft, PromptLookup};
use crate::coordinator::policy::AdaptivePolicy;
use crate::coordinator::pipeline::StridePolicy;
use crate::runtime::Registry;
use anyhow::Result;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    CloudOnly,
    Lookahead,
    StdSd,
    Pld,
    Medusa1,
    Eagle2,
    Dssd,
    FlexSpec,
}

impl Method {
    /// Table III/IV column order.
    pub fn table_columns() -> [Method; 7] {
        [
            Method::CloudOnly,
            Method::Lookahead,
            Method::StdSd,
            Method::Medusa1,
            Method::Eagle2,
            Method::Dssd,
            Method::FlexSpec,
        ]
    }

    pub fn all() -> [Method; 8] {
        [
            Method::CloudOnly,
            Method::Lookahead,
            Method::StdSd,
            Method::Pld,
            Method::Medusa1,
            Method::Eagle2,
            Method::Dssd,
            Method::FlexSpec,
        ]
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "cloud-only" | "cloud_only" | "cloudonly" => Method::CloudOnly,
            "lookahead" => Method::Lookahead,
            "std-sd" | "std_sd" | "stdsd" | "naive" => Method::StdSd,
            "pld" | "prompt-lookup" => Method::Pld,
            "medusa" | "medusa1" | "medusa-1" => Method::Medusa1,
            "eagle" | "eagle2" | "eagle-2" => Method::Eagle2,
            "dssd" => Method::Dssd,
            "flexspec" | "flex" => Method::FlexSpec,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::CloudOnly => "Cloud-Only",
            Method::Lookahead => "Lookahead",
            Method::StdSd => "Std. SD",
            Method::Pld => "PLD (n-gram)",
            Method::Medusa1 => "Medusa-1",
            Method::Eagle2 => "EAGLE-2",
            Method::Dssd => "DSSD",
            Method::FlexSpec => "FlexSpec",
        }
    }

    pub fn key(&self) -> &'static str {
        match self {
            Method::CloudOnly => "cloud_only",
            Method::Lookahead => "lookahead",
            Method::StdSd => "std_sd",
            Method::Pld => "pld",
            Method::Medusa1 => "medusa",
            Method::Eagle2 => "eagle2",
            Method::Dssd => "dssd",
            Method::FlexSpec => "flexspec",
        }
    }

    /// Table III/IV "Sync Required?" row.
    pub fn sync_required(&self) -> bool {
        matches!(self, Method::Medusa1 | Method::Eagle2)
    }

    /// What this method's uplink ships (see protocol::WireFormat): the
    /// wireless-aware designs send compact token indices; the
    /// tightly-coupled datacenter designs send their native verification
    /// payloads (candidate trees / head products / distribution
    /// sketches) unmodified.
    pub fn wire_format(&self) -> WireFormat {
        match self {
            Method::StdSd | Method::Medusa1 | Method::Eagle2 => WireFormat::Sketch,
            _ => WireFormat::Compact,
        }
    }

    /// Build the draft source for a (family, dataset-domain) pair.
    /// `domain` picks the synced bundle for the Synced baselines; it is
    /// the dataset's fine-tuning domain (nq for nq_rag).
    pub fn draft_source(
        &self,
        reg: &Registry,
        family: &str,
        domain: &str,
    ) -> Result<Box<dyn DraftSource>> {
        let dom = if domain == "nq_rag" { "nq" } else { domain };
        Ok(match self {
            Method::CloudOnly => Box::new(NoDraft),
            Method::Pld => Box::new(PromptLookup::pld(5)),
            Method::Lookahead => Box::new(PromptLookup::lookahead(4)),
            Method::StdSd => Box::new(ModelDraft::new(
                reg.model(&format!("draft_generic_{family}"))?,
            )?),
            Method::Medusa1 | Method::Eagle2 => {
                // "(Ideal Synced)": per-version re-distilled draft; falls
                // back to the flex draft when no synced bundle exists
                // (base-version targets).
                let synced = format!("draft_synced_{family}_{dom}");
                let name = if reg.manifest.weights.contains_key(&synced) {
                    synced
                } else {
                    format!("draft_flex_{family}")
                };
                Box::new(ModelDraft::new(reg.model(&name)?)?)
            }
            Method::Dssd | Method::FlexSpec => Box::new(ModelDraft::new(
                reg.model(&format!("draft_flex_{family}"))?,
            )?),
        })
    }

    /// Stride policy per method (K_max = 8 everywhere).
    pub fn stride_policy(&self, network: NetworkKind) -> StridePolicy {
        match self {
            Method::CloudOnly => StridePolicy::None,
            Method::StdSd | Method::Pld | Method::Lookahead => StridePolicy::Fixed(5),
            Method::Medusa1 => StridePolicy::Fixed(3), // 3 Medusa heads
            Method::Eagle2 => StridePolicy::Fixed(6),  // deep draft tree
            Method::Dssd => StridePolicy::Dssd {
                // class heuristic: knows the network TYPE, not the state
                base_k: match network {
                    NetworkKind::FiveG => 6,
                    NetworkKind::FourG => 4,
                    NetworkKind::WifiWeak => 2,
                },
                policy: AdaptivePolicy::new(8, 0.15),
            },
            Method::FlexSpec => StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.15)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.key()), Some(m), "{m:?}");
            assert!(!m.label().is_empty());
        }
        assert_eq!(Method::parse("EAGLE-2"), Some(Method::Eagle2));
        assert_eq!(Method::parse("quantum"), None);
    }

    #[test]
    fn sync_flags_match_paper_tables() {
        // Table III header: Sync Required? No No No Yes Yes No No
        let flags: Vec<bool> = Method::table_columns()
            .iter()
            .map(|m| m.sync_required())
            .collect();
        assert_eq!(flags, vec![false, false, false, true, true, false, false]);
    }

    #[test]
    fn stride_policies_differ_by_network_only_for_dssd() {
        let d5 = Method::Dssd.stride_policy(NetworkKind::FiveG);
        let dw = Method::Dssd.stride_policy(NetworkKind::WifiWeak);
        assert_ne!(format!("{d5:?}").len(), 0);
        match (d5, dw) {
            (StridePolicy::Dssd { base_k: a, .. }, StridePolicy::Dssd { base_k: b, .. }) => {
                assert!(a > b)
            }
            _ => panic!("dssd policy kind"),
        }
        match Method::FlexSpec.stride_policy(NetworkKind::WifiWeak) {
            StridePolicy::Adaptive(_) => {}
            _ => panic!("flexspec must be adaptive"),
        }
    }
}
