//! The deterministic autoscaling policy: pure decision logic over
//! telemetry snapshots.
//!
//! [`AutoscalePolicy::tick`] is a pure function of its accumulated
//! state and the snapshot slice it is handed — no clocks, no RNG, no
//! I/O — which is what lets the SAME policy drive the live fleet (the
//! [`controller`](super::controller) on the wall clock) and the load
//! harness's sim twin (`load::harness` on the virtual clock) and emit
//! byte-identical action logs for the same inputs. The log is
//! FNV-digested exactly like `LoadReport::digest`, extending the
//! repo's determinism contract to the control plane.
//!
//! # Hysteresis model
//!
//! Three mechanisms keep the loop from oscillating:
//!
//! * a **dead band** between `scale_down_queue` and `scale_up_queue` —
//!   mean queue depths inside the band reset both pressure counters,
//!   so noise near either threshold never accumulates into an action;
//! * **consecutive-tick pressure counters** — the mean queue must sit
//!   beyond a threshold for `up_ticks` (resp. `down_ticks`)
//!   consecutive ticks before a scale action fires, and `down_ticks`
//!   defaults much larger than `up_ticks` (scaling up is cheap and
//!   urgent, scaling down is neither);
//! * a **cooldown** of `cooldown_ticks` after every scale action,
//!   during which no further scale action can fire (rebalancing is
//!   exempt — moving sessions is how a freshly grown fleet absorbs
//!   load).
//!
//! Rebalancing has its own hysteresis: the most- and least-loaded
//! replicas must differ by BOTH a ratio (`rebalance_ratio`) and an
//! absolute margin (`rebalance_margin`) before any sessions move, and
//! at most `max_redirects_per_tick` move per tick. The per-session
//! redirect budget (`redirect_budget` per `redirect_window_ticks`) is
//! enforced by the actuators, which know session identity; the policy
//! only caps aggregate flow.

/// One replica as the policy sees it. Built from
/// [`ReplicaTelemetry`](crate::serve::ReplicaTelemetry) by the live
/// controller and from the harness's replica table by the sim twin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaSnapshot {
    /// Stable replica id (registry id live, replica index in the sim).
    pub id: u32,
    /// Sessions currently attached (decoding or between rounds).
    pub active: usize,
    /// Drafts waiting in the admission queue / backlog.
    pub queue: usize,
    /// True while the replica drains (never a rebalance target).
    pub draining: bool,
    /// Time since the snapshot was refreshed, ms. Snapshots older than
    /// [`AutoscaleConfig::staleness_ms`] are treated as UNKNOWN — a
    /// replica whose refreshes stopped must not keep winning placement
    /// on a stale low-load reading. `f64::INFINITY` = never refreshed.
    pub age_ms: f64,
}

impl ReplicaSnapshot {
    /// The same load scalar `ReplicaTelemetry::load()` reports.
    pub fn load(&self) -> usize {
        self.active + self.queue
    }
}

/// Policy knobs. `Default` is a conservative production shape; the
/// bench and the CLI override per scenario. All thresholds are in
/// "mean drafts queued per replica" units — the quantity the admission
/// queue bounds and `retry_after_ms` adapts to.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    /// Control-loop period, ms (wall or virtual).
    pub tick_ms: f64,
    /// Never scale below this many replicas.
    pub min_replicas: usize,
    /// Never scale above this many replicas.
    pub max_replicas: usize,
    /// Mean queue depth at/above which scale-up pressure accrues.
    pub scale_up_queue: usize,
    /// Mean queue depth at/below which scale-down pressure accrues.
    /// Must sit below `scale_up_queue`; the gap is the dead band.
    pub scale_down_queue: usize,
    /// Consecutive over-threshold ticks before a scale-up fires.
    pub up_ticks: u32,
    /// Consecutive under-threshold ticks before a scale-down fires.
    pub down_ticks: u32,
    /// Ticks after any scale action during which neither scale
    /// direction may fire again.
    pub cooldown_ticks: u32,
    /// Most replicas added by a single scale-up action.
    pub max_scale_step: usize,
    /// Max/min load ratio that arms a rebalance.
    pub rebalance_ratio: f64,
    /// Absolute load gap (drafts) the ratio must also clear.
    pub rebalance_margin: usize,
    /// Sessions moved per rebalance action, at most.
    pub max_redirects_per_tick: usize,
    /// Per-session redirect budget within one redirect window —
    /// enforced by the actuators (harness / registry), not here.
    pub redirect_budget: u8,
    /// Redirect-budget window length, in ticks.
    pub redirect_window_ticks: u32,
    /// Telemetry older than this is unknown (never preferred, never
    /// counted toward fleet sizing).
    pub staleness_ms: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> AutoscaleConfig {
        AutoscaleConfig {
            tick_ms: 1000.0,
            min_replicas: 1,
            max_replicas: 64,
            scale_up_queue: 6,
            scale_down_queue: 1,
            up_ticks: 3,
            down_ticks: 10,
            cooldown_ticks: 5,
            max_scale_step: 4,
            rebalance_ratio: 2.0,
            rebalance_margin: 4,
            max_redirects_per_tick: 4,
            redirect_budget: 2,
            redirect_window_ticks: 30,
            staleness_ms: 2000.0,
        }
    }
}

/// One control decision. Replica-granular: the actuation layer maps
/// these onto `FleetRegistry` primitives (live) or the harness's
/// replica table (sim).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AutoscaleAction {
    /// Spawn `add` fresh replicas.
    ScaleUp { add: usize },
    /// Drain and retire the replica with this id.
    ScaleDown { victim: u32 },
    /// Move up to `sessions` sessions from `from` to `to` at their
    /// next head round.
    Rebalance { from: u32, to: u32, sessions: usize },
}

impl AutoscaleAction {
    /// Stable numeric code, digested into the action log.
    pub fn code(&self) -> u64 {
        match self {
            AutoscaleAction::ScaleUp { .. } => 1,
            AutoscaleAction::ScaleDown { .. } => 2,
            AutoscaleAction::Rebalance { .. } => 3,
        }
    }

    /// The action's arguments as three u64s (unused ones 0) — the
    /// digest fold and the trace journal share this encoding.
    pub fn args(&self) -> (u64, u64, u64) {
        match *self {
            AutoscaleAction::ScaleUp { add } => (add as u64, 0, 0),
            AutoscaleAction::ScaleDown { victim } => (victim as u64, 0, 0),
            AutoscaleAction::Rebalance { from, to, sessions } => {
                (from as u64, to as u64, sessions as u64)
            }
        }
    }

    /// Human line for action-log exports.
    pub fn describe(&self) -> String {
        match *self {
            AutoscaleAction::ScaleUp { add } => format!("scale_up add={add}"),
            AutoscaleAction::ScaleDown { victim } => format!("scale_down victim={victim}"),
            AutoscaleAction::Rebalance { from, to, sessions } => {
                format!("rebalance from={from} to={to} sessions={sessions}")
            }
        }
    }
}

/// The control loop's brain: feed it one snapshot slice per tick, get
/// back the actions to apply. Accumulates the full `(tick, action)`
/// log; [`AutoscalePolicy::log_digest`] is the byte-identity pin.
#[derive(Debug, Clone)]
pub struct AutoscalePolicy {
    cfg: AutoscaleConfig,
    up_for: u32,
    down_for: u32,
    cooldown: u32,
    log: Vec<(u64, AutoscaleAction)>,
}

impl AutoscalePolicy {
    pub fn new(cfg: AutoscaleConfig) -> AutoscalePolicy {
        AutoscalePolicy {
            cfg,
            up_for: 0,
            down_for: 0,
            cooldown: 0,
            log: Vec::new(),
        }
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// The accumulated `(tick, action)` log.
    pub fn log(&self) -> &[(u64, AutoscaleAction)] {
        &self.log
    }

    /// Order-sensitive FNV-1a fold over the action log — same idiom as
    /// `LoadReport::digest`. Two runs of the same config + seed must
    /// produce byte-identical logs, hence equal digests.
    pub fn log_digest(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        for (tick, action) in &self.log {
            mix(*tick);
            mix(action.code());
            let (a, b, c) = action.args();
            mix(a);
            mix(b);
            mix(c);
        }
        h
    }

    /// One control step. `snaps` should cover every non-retired
    /// replica; stale and draining entries are ignored for sizing and
    /// placement (a drain in progress IS the previous decision still
    /// executing). Returns the actions in a deterministic order:
    /// at most one scale action, then at most one rebalance.
    pub fn tick(&mut self, tick: u64, snaps: &[ReplicaSnapshot]) -> Vec<AutoscaleAction> {
        let cfg = &self.cfg;
        let known: Vec<&ReplicaSnapshot> = snaps
            .iter()
            .filter(|s| !s.draining && s.age_ms <= cfg.staleness_ms)
            .collect();
        let mut out = Vec::new();
        if self.cooldown > 0 {
            self.cooldown -= 1;
        }
        if known.is_empty() {
            // flying blind: hold state, take no action
            return out;
        }
        let n = known.len();
        let mean_q = known.iter().map(|s| s.queue).sum::<usize>() as f64 / n as f64;

        // pressure accrual with a dead band between the thresholds
        if mean_q >= cfg.scale_up_queue as f64 {
            self.up_for += 1;
            self.down_for = 0;
        } else if mean_q <= cfg.scale_down_queue as f64 {
            self.down_for += 1;
            self.up_for = 0;
        } else {
            self.up_for = 0;
            self.down_for = 0;
        }

        if self.cooldown == 0 && self.up_for >= cfg.up_ticks && n < cfg.max_replicas {
            // size the step to the overload: one replica per
            // `scale_up_queue` of mean depth, bounded by the step cap
            // and the fleet ceiling
            let add = ((mean_q / cfg.scale_up_queue.max(1) as f64) as usize)
                .clamp(1, cfg.max_scale_step)
                .min(cfg.max_replicas - n);
            out.push(AutoscaleAction::ScaleUp { add });
            self.up_for = 0;
            self.cooldown = cfg.cooldown_ticks;
        } else if self.cooldown == 0 && self.down_for >= cfg.down_ticks && n > cfg.min_replicas {
            // retire the least-loaded replica; its sessions drain to
            // peers through the ledger (never stranded)
            let victim = known
                .iter()
                .min_by_key(|s| (s.load(), s.id))
                .expect("known is non-empty")
                .id;
            out.push(AutoscaleAction::ScaleDown { victim });
            self.down_for = 0;
            self.cooldown = cfg.cooldown_ticks;
        }

        // load-adaptive rebalancing — exempt from the scale cooldown
        if n >= 2 {
            let most = known
                .iter()
                .max_by_key(|s| (s.load(), s.id))
                .expect("known is non-empty");
            let least = known
                .iter()
                .min_by_key(|s| (s.load(), s.id))
                .expect("known is non-empty");
            let gap = most.load().saturating_sub(least.load());
            if most.id != least.id
                && most.load() as f64 >= cfg.rebalance_ratio * least.load().max(1) as f64
                && gap >= cfg.rebalance_margin
            {
                let sessions = (gap / 2).clamp(1, cfg.max_redirects_per_tick);
                out.push(AutoscaleAction::Rebalance {
                    from: most.id,
                    to: least.id,
                    sessions,
                });
            }
        }

        for a in &out {
            self.log.push((tick, *a));
        }
        out
    }
}

/// Queue-depth-adaptive Busy backoff: the static suggestion was one
/// admission window regardless of backlog; under a deep queue that
/// made every deferred edge retry into the SAME congested window.
/// This scales the suggestion by how many windows the present backlog
/// needs to drain (`1 + queue_len / max_batch`), capped at 16 windows
/// so a transient spike cannot park edges for minutes. At
/// `queue_len == 0` it equals the old static value, so unsaturated
/// behavior is unchanged. Pure — the verifier and the load harness
/// call the same function, keeping sim == serve.
pub fn adaptive_retry_after_ms(window_ms: f64, queue_len: usize, max_batch: usize) -> u32 {
    let base = window_ms.max(1.0).ceil() as u32;
    let windows = 1 + queue_len / max_batch.max(1);
    (base.saturating_mul(windows as u32)).min(base.saturating_mul(16))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(id: u32, active: usize, queue: usize) -> ReplicaSnapshot {
        ReplicaSnapshot {
            id,
            active,
            queue,
            draining: false,
            age_ms: 0.0,
        }
    }

    #[test]
    fn adaptive_retry_matches_static_when_idle_and_grows_with_backlog() {
        // empty queue: exactly the old static suggestion
        assert_eq!(adaptive_retry_after_ms(12.0, 0, 8), 12);
        assert_eq!(adaptive_retry_after_ms(0.25, 0, 8), 1);
        // one extra window per max_batch of backlog
        assert_eq!(adaptive_retry_after_ms(12.0, 8, 8), 24);
        assert_eq!(adaptive_retry_after_ms(12.0, 40, 8), 72);
        // capped at 16 windows
        assert_eq!(adaptive_retry_after_ms(12.0, 100_000, 8), 192);
        // degenerate max_batch never divides by zero
        assert_eq!(adaptive_retry_after_ms(12.0, 3, 0), 48);
    }

    #[test]
    fn scale_up_needs_consecutive_pressure_and_respects_cooldown() {
        let cfg = AutoscaleConfig {
            up_ticks: 3,
            cooldown_ticks: 4,
            max_scale_step: 1,
            ..AutoscaleConfig::default()
        };
        let mut p = AutoscalePolicy::new(cfg);
        let hot = [snap(1, 4, 20), snap(2, 4, 20)];
        assert!(p.tick(0, &hot).is_empty());
        assert!(p.tick(1, &hot).is_empty());
        let a = p.tick(2, &hot);
        assert_eq!(a, vec![AutoscaleAction::ScaleUp { add: 1 }]);
        // cooldown: pressure keeps accruing but nothing fires
        for t in 3..6 {
            assert!(
                !p.tick(t, &hot).iter().any(|a| matches!(a, AutoscaleAction::ScaleUp { .. })),
                "scale-up fired during cooldown at tick {t}"
            );
        }
        let again = p.tick(6, &hot);
        assert!(again.iter().any(|a| matches!(a, AutoscaleAction::ScaleUp { .. })));
    }

    #[test]
    fn dead_band_resets_pressure_so_noise_never_scales() {
        let cfg = AutoscaleConfig {
            scale_up_queue: 6,
            scale_down_queue: 1,
            up_ticks: 2,
            ..AutoscaleConfig::default()
        };
        let mut p = AutoscalePolicy::new(cfg);
        let hot = [snap(1, 0, 8)];
        let mid = [snap(1, 0, 3)]; // inside the dead band
        for t in 0..20 {
            // alternating hot/mid never accrues up_ticks consecutive
            let s = if t % 2 == 0 { &hot } else { &mid };
            let acts = p.tick(t, s);
            assert!(acts.is_empty(), "oscillating load scaled at tick {t}: {acts:?}");
        }
    }

    #[test]
    fn scale_down_retires_least_loaded_and_floors_at_min() {
        let cfg = AutoscaleConfig {
            min_replicas: 2,
            down_ticks: 2,
            cooldown_ticks: 0,
            ..AutoscaleConfig::default()
        };
        let mut p = AutoscalePolicy::new(cfg);
        let idle = [snap(1, 2, 0), snap(2, 0, 0), snap(3, 1, 0)];
        assert!(p.tick(0, &idle).is_empty());
        let a = p.tick(1, &idle);
        assert_eq!(a, vec![AutoscaleAction::ScaleDown { victim: 2 }]);
        // at the floor, nothing more comes off
        let two = [snap(1, 0, 0), snap(3, 0, 0)];
        assert!(p.tick(2, &two).is_empty());
        assert!(p.tick(3, &two).is_empty());
        assert!(p.tick(4, &two).is_empty());
    }

    #[test]
    fn rebalance_needs_ratio_and_margin_and_caps_flow() {
        let cfg = AutoscaleConfig {
            rebalance_ratio: 2.0,
            rebalance_margin: 4,
            max_redirects_per_tick: 3,
            ..AutoscaleConfig::default()
        };
        let mut p = AutoscalePolicy::new(cfg);
        // ratio met but margin not: 3 vs 1
        assert!(p.tick(0, &[snap(1, 3, 0), snap(2, 1, 0)]).is_empty());
        // margin met but ratio not: 10 vs 6
        assert!(p.tick(1, &[snap(1, 10, 0), snap(2, 6, 0)]).is_empty());
        // both met: flow capped at max_redirects_per_tick
        let a = p.tick(2, &[snap(1, 20, 0), snap(2, 2, 0)]);
        assert_eq!(
            a,
            vec![AutoscaleAction::Rebalance { from: 1, to: 2, sessions: 3 }]
        );
    }

    #[test]
    fn stale_snapshots_are_never_preferred_or_counted() {
        let cfg = AutoscaleConfig {
            staleness_ms: 1000.0,
            rebalance_margin: 2,
            ..AutoscaleConfig::default()
        };
        let mut p = AutoscalePolicy::new(cfg);
        // the stale replica reads empty — without the staleness gate it
        // would win every rebalance and soak up redirected sessions
        let stale_min = [
            snap(1, 9, 0),
            snap(2, 1, 0),
            ReplicaSnapshot { age_ms: 5000.0, ..snap(3, 0, 0) },
        ];
        let a = p.tick(0, &stale_min);
        assert_eq!(
            a,
            vec![AutoscaleAction::Rebalance { from: 1, to: 2, sessions: 4 }],
            "rebalance must target the freshest least-loaded replica, not the stale one"
        );
        // a fully stale fleet takes no action at all
        let blind = [
            ReplicaSnapshot { age_ms: 5000.0, ..snap(1, 0, 50) },
            ReplicaSnapshot { age_ms: f64::INFINITY, ..snap(2, 0, 50) },
        ];
        assert!(p.tick(1, &blind).is_empty());
    }

    #[test]
    fn log_digest_is_deterministic_and_order_sensitive() {
        let run = || {
            let mut p = AutoscalePolicy::new(AutoscaleConfig {
                up_ticks: 1,
                cooldown_ticks: 0,
                ..AutoscaleConfig::default()
            });
            for t in 0..10 {
                p.tick(t, &[snap(1, 4, 30), snap(2, 0, 0)]);
            }
            p.log_digest()
        };
        assert_eq!(run(), run());
        let mut other = AutoscalePolicy::new(AutoscaleConfig::default());
        other.tick(0, &[snap(1, 0, 0)]);
        assert_ne!(run(), other.log_digest());
        // empty log digests to the FNV offset basis, consistently
        assert_eq!(
            AutoscalePolicy::new(AutoscaleConfig::default()).log_digest(),
            0xcbf2_9ce4_8422_2325
        );
    }

    #[test]
    fn skewed_fleet_converges_within_bounded_ticks() {
        // a model fleet: apply the policy's own rebalances to synthetic
        // loads and require convergence below the margin within N ticks
        let cfg = AutoscaleConfig {
            rebalance_margin: 4,
            max_redirects_per_tick: 4,
            ..AutoscaleConfig::default()
        };
        for seed_skew in [40usize, 25, 13] {
            let mut p = AutoscalePolicy::new(cfg.clone());
            let mut loads = [seed_skew, 2, 3, 1];
            let mut converged_at = None;
            for t in 0..64u64 {
                let snaps: Vec<ReplicaSnapshot> = loads
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| snap(i as u32, l, 0))
                    .collect();
                for a in p.tick(t, &snaps) {
                    if let AutoscaleAction::Rebalance { from, to, sessions } = a {
                        let n = sessions.min(loads[from as usize]);
                        loads[from as usize] -= n;
                        loads[to as usize] += n;
                    }
                }
                let (max, min) = (
                    *loads.iter().max().unwrap(),
                    *loads.iter().min().unwrap(),
                );
                if max - min < cfg.rebalance_margin {
                    converged_at = Some(t);
                    break;
                }
            }
            let t = converged_at.expect("fleet never converged");
            assert!(t <= 16, "skew {seed_skew} took {t} ticks to converge");
            // and once converged it STAYS converged (no ping-pong)
            for t in 100..110u64 {
                let snaps: Vec<ReplicaSnapshot> = loads
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| snap(i as u32, l, 0))
                    .collect();
                let acts = p.tick(t, &snaps);
                assert!(
                    !acts.iter().any(|a| matches!(a, AutoscaleAction::Rebalance { .. })),
                    "balanced fleet kept rebalancing: {acts:?}"
                );
            }
        }
    }
}
