//! The live actuation layer: one [`AutoscaleController`] per fleet,
//! ticked on a fixed period, translating [`AutoscalePolicy`] decisions
//! into [`FleetRegistry`] calls.
//!
//! The controller deliberately does NOT spawn replicas itself —
//! spawning needs a backend factory and a bind address scheme that
//! belong to the embedding layer (`serve-cloud --autoscale` in
//! `cli_entry`, loopback factories in tests). [`AutoscaleController::
//! step`] applies `ScaleDown` (drain toward the best peer) and
//! `Rebalance` (targeted `redirect_some`) itself and RETURNS the full
//! action list so the caller can honor `ScaleUp` with whatever
//! replica-construction recipe it owns. The sim twin in
//! `load::harness` applies the same action vocabulary to its replica
//! table — both sides consume the identical policy, so for the same
//! snapshot stream the action logs are byte-identical.

use anyhow::Result;

use super::policy::{AutoscaleAction, AutoscaleConfig, AutoscalePolicy, ReplicaSnapshot};
use crate::obs::{SpanKind, Trace};
use crate::serve::fleet::FleetRegistry;

/// Pseudo session id autoscale span events journal under: fleet-level
/// control actions have no session of their own, and this id can never
/// collide with a server-assigned one (those start at 1 and a fleet
/// never reaches 2^32-1 concurrent sessions in-process).
pub const CONTROL_SESSION: u32 = u32::MAX;

/// Drives one fleet's autoscaling loop. Construct once, call
/// [`AutoscaleController::step`] every `cfg.tick_ms`.
pub struct AutoscaleController {
    policy: AutoscalePolicy,
    tick: u64,
}

impl AutoscaleController {
    pub fn new(cfg: AutoscaleConfig) -> AutoscaleController {
        AutoscaleController {
            policy: AutoscalePolicy::new(cfg),
            tick: 0,
        }
    }

    /// The policy (action log + digest live here).
    pub fn policy(&self) -> &AutoscalePolicy {
        &self.policy
    }

    /// Ticks taken so far.
    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// Build policy snapshots from the registry's current view.
    /// Quarantined replicas are invisible to the policy (the operator
    /// verdict outranks it); replicas whose refresh is older than the
    /// staleness window surface with their true age and are discounted
    /// inside the policy.
    pub fn snapshots(registry: &FleetRegistry, now_ms: f64) -> Vec<ReplicaSnapshot> {
        registry
            .replicas()
            .iter()
            .filter(|r| !r.quarantined)
            .map(|r| {
                let (active, queue) = r
                    .last
                    .as_ref()
                    .map(|t| (t.active_sessions, t.queue_len))
                    .unwrap_or((0, 0));
                ReplicaSnapshot {
                    id: r.id,
                    active,
                    queue,
                    draining: r.draining,
                    age_ms: r.age_ms(now_ms),
                }
            })
            .collect()
    }

    /// One control tick against the live fleet: refresh telemetry,
    /// decide, actuate `ScaleDown`/`Rebalance`, journal span events,
    /// and return every decided action (the caller spawns replicas for
    /// `ScaleUp` and retires fully-drained victims at its own pace).
    pub async fn step(
        &mut self,
        registry: &mut FleetRegistry,
        now_ms: f64,
        trace: Option<&Trace>,
    ) -> Result<Vec<AutoscaleAction>> {
        registry.refresh(now_ms).await;
        let snaps = Self::snapshots(registry, now_ms);
        let tick = self.tick;
        self.tick += 1;
        let actions = self.policy.tick(tick, &snaps);
        for action in &actions {
            if let Some(tr) = trace {
                let (a, _, _) = action.args();
                tr.record(
                    CONTROL_SESSION,
                    tick as u32,
                    SpanKind::Autoscale,
                    0.0,
                    action.code() as u32,
                    a as u32,
                );
            }
            match *action {
                AutoscaleAction::ScaleUp { .. } => {} // caller-owned
                AutoscaleAction::ScaleDown { victim } => {
                    let addr = registry
                        .replicas()
                        .iter()
                        .find(|r| r.id == victim)
                        .map(|r| r.addr.clone());
                    if let Some(addr) = addr {
                        if let Some(to) = registry.pick_peer(&addr, now_ms) {
                            registry.drain(&addr, &to)?;
                        }
                    }
                }
                AutoscaleAction::Rebalance { from, to, sessions } => {
                    let addr_of = |id: u32| {
                        registry
                            .replicas()
                            .iter()
                            .find(|r| r.id == id)
                            .map(|r| r.addr.clone())
                    };
                    if let (Some(from), Some(to)) = (addr_of(from), addr_of(to)) {
                        registry.rebalance(&from, &to, sessions).await?;
                    }
                }
            }
        }
        Ok(actions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{SyntheticTarget, VerifierConfig, VerifyBackend};

    fn rt() -> tokio::runtime::Runtime {
        tokio::runtime::Builder::new_current_thread()
            .enable_all()
            .build()
            .unwrap()
    }

    fn make_backend() -> Result<Box<dyn VerifyBackend>> {
        Ok(Box::new(SyntheticTarget::new(5)) as Box<dyn VerifyBackend>)
    }

    #[test]
    fn controller_drains_the_scale_down_victim() {
        rt().block_on(async {
            let mut reg = FleetRegistry::new();
            reg.spawn_loopback_replica("replica-a", VerifierConfig::default(), make_backend)
                .unwrap();
            reg.spawn_loopback_replica("replica-b", VerifierConfig::default(), make_backend)
                .unwrap();
            let cfg = AutoscaleConfig {
                min_replicas: 1,
                down_ticks: 2,
                cooldown_ticks: 0,
                ..AutoscaleConfig::default()
            };
            let mut ctl = AutoscaleController::new(cfg);
            // two idle ticks accrue scale-down pressure; the third
            // tick's decision drains a victim toward its peer
            let mut drained = false;
            for t in 0..4 {
                let acts = ctl.step(&mut reg, t as f64 * 1000.0, None).await.unwrap();
                if acts
                    .iter()
                    .any(|a| matches!(a, AutoscaleAction::ScaleDown { .. }))
                {
                    drained = true;
                }
            }
            assert!(drained, "idle two-replica fleet never scaled down");
            assert_eq!(
                reg.replicas().iter().filter(|r| r.draining).count(),
                1,
                "exactly one replica should be draining"
            );
            assert_eq!(ctl.policy().log().len(), 1);
            assert!(ctl.ticks() >= 3);
            for r in reg.replicas() {
                r.verifier.shutdown().await.unwrap();
            }
        });
    }

    #[test]
    fn controller_snapshots_track_age_and_quarantine() {
        rt().block_on(async {
            let mut reg = FleetRegistry::new();
            reg.spawn_loopback_replica("replica-a", VerifierConfig::default(), make_backend)
                .unwrap();
            reg.spawn_loopback_replica("replica-b", VerifierConfig::default(), make_backend)
                .unwrap();
            reg.refresh(100.0).await;
            let snaps = AutoscaleController::snapshots(&reg, 150.0);
            assert_eq!(snaps.len(), 2);
            assert!(snaps.iter().all(|s| (s.age_ms - 50.0).abs() < 1e-9));
            reg.mark_dead("replica-b");
            let snaps = AutoscaleController::snapshots(&reg, 150.0);
            assert_eq!(snaps.len(), 1, "quarantined replicas are invisible");
            for r in reg.replicas() {
                r.verifier.shutdown().await.unwrap();
            }
        });
    }
}
