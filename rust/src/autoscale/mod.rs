//! `flexspec::autoscale` — the closed-loop fleet control plane
//! (ROADMAP item 3; see `docs/AUTOSCALE.md`).
//!
//! PR 5's [`FleetRegistry`](crate::serve::FleetRegistry) grew every
//! actuator a fleet needs — telemetry, `pick_peer`, targeted
//! redirects, drain/undrain, canary rollout — and PR 7's
//! `flexspec::load` harness built a deterministic million-session
//! testbed. This module is the brain between them:
//!
//! * [`policy`] — the pure decision loop: [`AutoscalePolicy::tick`]
//!   consumes [`ReplicaSnapshot`]s and emits [`AutoscaleAction`]s
//!   (scale-up, drain-and-retire, bounded rebalancing) under triple
//!   hysteresis (dead band, consecutive-tick pressure, cooldown), plus
//!   [`adaptive_retry_after_ms`] — the queue-depth-adaptive Busy
//!   suggestion shared by the live verifier and the load harness.
//! * [`controller`] — the live actuation layer: a tick thread in
//!   `serve-cloud --autoscale` refreshes the registry, runs the
//!   policy, and applies drains/redirects; `ScaleUp` is returned to
//!   the embedding layer, which owns replica construction.
//!
//! The sim twin lives in `load::harness` (an `AutoscaleTick` event on
//! the virtual clock applying the same action vocabulary to the
//! simulated replica table). Because the policy is pure and both
//! actuation layers consume it identically, the determinism contract
//! extends to the control plane: same config + seed ⇒ byte-identical
//! action log ([`AutoscalePolicy::log_digest`], FNV-folded like
//! `LoadReport::digest`) and byte-identical committed sequences.

pub mod controller;
pub mod policy;

pub use controller::{AutoscaleController, CONTROL_SESSION};
pub use policy::{
    adaptive_retry_after_ms, AutoscaleAction, AutoscaleConfig, AutoscalePolicy, ReplicaSnapshot,
};
