//! Heterogeneous edge device layer (wire v8, ROADMAP item 4).
//!
//! Everything before this module assumed ONE edge archetype per run.
//! Here the fleet becomes a population of unlike devices: each session
//! carries a [`DeviceProfile`] — compute tier, channel class, energy
//! budget — on its `Open`, and the resource-aware policy extension
//! ([`crate::coordinator::AdaptivePolicy::select_plan`]) turns that
//! profile plus the measured channel into a joint speculation plan
//! ([`SpecPlan`]): stride K, pipeline depth, and draft BRANCHING factor
//! for tree speculation.
//!
//! The tier → plan-cap table is deliberately coarse (three tiers, small
//! caps) and MONOTONE: a weaker tier never receives a larger plan along
//! any axis, and a draining energy budget only ever steps a session
//! down the same table. That monotonicity is what keeps the policy
//! deterministic enough to pin live == sim byte-identically: branching
//! is a pure function of (tier, remaining-energy fraction, config cap)
//! and never of the noisy channel sample.
//!
//! Grounded in PAPERS.md: "Efficient LLM Inference over Heterogeneous
//! Edge Networks with Speculative Decoding" (per-device joint parameter
//! optimization) and "Collaborative Large Language Model Inference via
//! Resource-Aware Parallel Speculative Decoding" (resource-aware
//! branching drafts).

use crate::devices::{EdgeDevice, IPHONE_15_PRO_MAX, JETSON_ORIN, RASPBERRY_PI_5};
use crate::protocol::frame::DeviceProfileMsg;
use crate::util::rng::SplitMix64;

/// Branching-factor ceiling the wire and the verifier plan for
/// (`DraftMsg::tree` node indices are u8 and the comb expansion keeps
/// every alternate a single-token leaf).
pub const MAX_BRANCHING: usize = 4;

/// Coarse compute class of an edge device — the axis the plan-cap table
/// is keyed on. Derived from the device's measured draft speed so the
/// tier is a property of the hardware, not a config knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ComputeTier {
    /// CPU-class drafting (Raspberry Pi 5: ~7 tok/s). Speculation barely
    /// pays; keep strides short and never branch.
    Weak,
    /// Phone-NPU-class drafting (iPhone / Snapdragon: ~80–95 tok/s).
    Mid,
    /// Embedded-GPU-class drafting (Jetson Orin: ~118 tok/s). Full
    /// strides, deep pipelines, widest trees.
    Strong,
}

impl ComputeTier {
    /// Classify a device by its marginal draft latency.
    pub fn of(device: &EdgeDevice) -> ComputeTier {
        if device.draft_ms_per_token < 10.0 {
            ComputeTier::Strong
        } else if device.draft_ms_per_token < 40.0 {
            ComputeTier::Mid
        } else {
            ComputeTier::Weak
        }
    }

    /// Wire code ([`DeviceProfileMsg::compute_tier`]).
    pub fn code(self) -> u8 {
        match self {
            ComputeTier::Weak => 0,
            ComputeTier::Mid => 1,
            ComputeTier::Strong => 2,
        }
    }

    pub fn from_code(code: u8) -> Option<ComputeTier> {
        Some(match code {
            0 => ComputeTier::Weak,
            1 => ComputeTier::Mid,
            2 => ComputeTier::Strong,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        match self {
            ComputeTier::Weak => "weak",
            ComputeTier::Mid => "mid",
            ComputeTier::Strong => "strong",
        }
    }

    pub fn all() -> [ComputeTier; 3] {
        [ComputeTier::Weak, ComputeTier::Mid, ComputeTier::Strong]
    }

    /// The next weaker tier (saturating) — the step a draining energy
    /// budget takes down the cap table.
    pub fn weaker(self) -> ComputeTier {
        match self {
            ComputeTier::Strong => ComputeTier::Mid,
            _ => ComputeTier::Weak,
        }
    }

    /// Per-tier plan ceilings. Componentwise monotone in the tier — the
    /// invariant [`AdaptivePolicy::select_plan`]'s monotonicity proof
    /// (and its property test) rests on.
    ///
    /// [`AdaptivePolicy::select_plan`]: crate::coordinator::AdaptivePolicy::select_plan
    pub fn plan_caps(self) -> SpecPlan {
        match self {
            ComputeTier::Weak => SpecPlan { k: 2, depth: 1, branching: 1 },
            ComputeTier::Mid => SpecPlan { k: 4, depth: 2, branching: 2 },
            ComputeTier::Strong => SpecPlan { k: 8, depth: 4, branching: MAX_BRANCHING },
        }
    }

    /// Representative hardware for the tier — what the load harness and
    /// the device-mix CLI instantiate per simulated session.
    pub fn representative(self) -> &'static EdgeDevice {
        match self {
            ComputeTier::Weak => &RASPBERRY_PI_5,
            ComputeTier::Mid => &IPHONE_15_PRO_MAX,
            ComputeTier::Strong => &JETSON_ORIN,
        }
    }
}

/// A joint speculation plan: what one session should do THIS round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecPlan {
    /// Draft stride (main-chain depth), 1..=8.
    pub k: usize,
    /// Pipelined rounds in flight (1 = sequential).
    pub depth: usize,
    /// Draft tree branching factor (1 = linear chain).
    pub branching: usize,
}

impl SpecPlan {
    /// Componentwise minimum — how caps compose.
    pub fn min(self, other: SpecPlan) -> SpecPlan {
        SpecPlan {
            k: self.k.min(other.k),
            depth: self.depth.min(other.depth),
            branching: self.branching.min(other.branching),
        }
    }

    /// `self` never exceeds `other` on any axis.
    pub fn fits_within(self, other: SpecPlan) -> bool {
        self.k <= other.k && self.depth <= other.depth && self.branching <= other.branching
    }
}

/// Who a session's edge is: the wire-v8 `Open` payload's local form.
#[derive(Debug, Clone, Copy)]
pub struct DeviceProfile {
    pub device: &'static EdgeDevice,
    pub tier: ComputeTier,
    /// Channel class index into [`crate::channel::NetworkKind::all`].
    pub channel_class: u8,
    /// Session energy budget, joules (0 = unmetered).
    pub energy_budget_j: f64,
}

impl DeviceProfile {
    pub fn new(device: &'static EdgeDevice, channel_class: u8, energy_budget_j: f64) -> DeviceProfile {
        DeviceProfile {
            device,
            tier: ComputeTier::of(device),
            channel_class,
            energy_budget_j,
        }
    }

    /// Unmetered profile on the default channel — the pre-v8 archetype.
    pub fn of(device: &'static EdgeDevice) -> DeviceProfile {
        DeviceProfile::new(device, 0, 0.0)
    }

    /// Wire form, carrying the REMAINING budget (what the cloud can act
    /// on at open time).
    pub fn to_wire(&self, remaining_j: f64) -> DeviceProfileMsg {
        DeviceProfileMsg {
            compute_tier: self.tier.code(),
            channel_class: self.channel_class,
            energy_mj: (remaining_j.max(0.0) * 1e3).round() as u64,
        }
    }
}

/// Tier mix for a heterogeneous fleet — the device axis twin of
/// `load::population::ChannelMix`. Weights order: [weak, mid, strong].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceMix {
    pub weights: [f64; 3],
}

impl DeviceMix {
    /// Single-archetype mix (everyone strong) — the pre-v8 behavior.
    pub const UNIFORM_STRONG: DeviceMix = DeviceMix { weights: [0.0, 0.0, 1.0] };

    /// Evaluation mix: a quarter CPU-class stragglers, half phones, a
    /// quarter embedded GPUs — the hetero bench/test operating point.
    pub const EVAL: DeviceMix = DeviceMix { weights: [0.25, 0.5, 0.25] };

    pub fn new(weak: f64, mid: f64, strong: f64) -> DeviceMix {
        assert!(weak >= 0.0 && mid >= 0.0 && strong >= 0.0, "negative mix weight");
        assert!(weak + mid + strong > 0.0, "empty device mix");
        DeviceMix { weights: [weak, mid, strong] }
    }

    /// Parse `"0.25,0.5,0.25"` (weak,mid,strong) or the aliases
    /// `"eval"` / `"strong"`.
    pub fn parse(s: &str) -> Result<DeviceMix, String> {
        match s {
            "eval" => return Ok(DeviceMix::EVAL),
            "strong" => return Ok(DeviceMix::UNIFORM_STRONG),
            _ => {}
        }
        let parts: Vec<f64> = s
            .split(',')
            .map(|p| p.trim().parse::<f64>().map_err(|e| format!("device mix `{s}`: {e}")))
            .collect::<Result<_, _>>()?;
        if parts.len() != 3 {
            return Err(format!("device mix `{s}`: want 3 weights (weak,mid,strong)"));
        }
        if parts.iter().any(|&w| w < 0.0) || parts.iter().sum::<f64>() <= 0.0 {
            return Err(format!("device mix `{s}`: weights must be >= 0 and sum > 0"));
        }
        Ok(DeviceMix { weights: [parts[0], parts[1], parts[2]] })
    }

    /// Draw a tier (one rng draw, mirroring `ChannelMix::pick`).
    pub fn pick(&self, rng: &mut SplitMix64) -> ComputeTier {
        let total: f64 = self.weights.iter().sum();
        let mut x = rng.next_f64() * total;
        for (i, &w) in self.weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return ComputeTier::from_code(i as u8).unwrap();
            }
        }
        ComputeTier::Strong
    }

    pub fn describe(&self) -> String {
        let total: f64 = self.weights.iter().sum();
        format!(
            "weak {:.0}% / mid {:.0}% / strong {:.0}%",
            self.weights[0] / total * 100.0,
            self.weights[1] / total * 100.0,
            self.weights[2] / total * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::SNAPDRAGON_8G3;

    #[test]
    fn tiers_classify_the_table5_devices() {
        assert_eq!(ComputeTier::of(&RASPBERRY_PI_5), ComputeTier::Weak);
        assert_eq!(ComputeTier::of(&IPHONE_15_PRO_MAX), ComputeTier::Mid);
        assert_eq!(ComputeTier::of(&SNAPDRAGON_8G3), ComputeTier::Mid);
        assert_eq!(ComputeTier::of(&JETSON_ORIN), ComputeTier::Strong);
        for t in ComputeTier::all() {
            assert_eq!(ComputeTier::from_code(t.code()), Some(t));
            assert_eq!(ComputeTier::of(t.representative()), t);
        }
        assert_eq!(ComputeTier::from_code(3), None);
    }

    #[test]
    fn plan_caps_are_monotone_in_tier() {
        let [w, m, s] = ComputeTier::all().map(|t| t.plan_caps());
        assert!(w.fits_within(m) && m.fits_within(s));
        assert_eq!(w.branching, 1, "weak tier never branches");
        assert!(s.branching <= MAX_BRANCHING);
        // energy downgrade walks the same table and terminates at Weak
        assert_eq!(ComputeTier::Strong.weaker(), ComputeTier::Mid);
        assert_eq!(ComputeTier::Mid.weaker(), ComputeTier::Weak);
        assert_eq!(ComputeTier::Weak.weaker(), ComputeTier::Weak);
    }

    #[test]
    fn profile_round_trips_to_wire() {
        let p = DeviceProfile::new(&IPHONE_15_PRO_MAX, 1, 120.0);
        let w = p.to_wire(84.5);
        assert_eq!(w.compute_tier, ComputeTier::Mid.code());
        assert_eq!(w.channel_class, 1);
        assert_eq!(w.energy_mj, 84_500);
        // the default archetype is unmetered on channel class 0
        let d = DeviceProfile::of(&JETSON_ORIN);
        assert_eq!(d.to_wire(0.0).energy_mj, 0);
        assert_eq!(d.tier, ComputeTier::Strong);
    }

    #[test]
    fn device_mix_parses_and_picks_deterministically() {
        assert_eq!(DeviceMix::parse("eval").unwrap(), DeviceMix::EVAL);
        assert_eq!(DeviceMix::parse("strong").unwrap(), DeviceMix::UNIFORM_STRONG);
        let m = DeviceMix::parse("1,0,0").unwrap();
        let mut rng = SplitMix64::new(7);
        for _ in 0..32 {
            assert_eq!(m.pick(&mut rng), ComputeTier::Weak);
        }
        assert!(DeviceMix::parse("0.5,0.5").is_err());
        assert!(DeviceMix::parse("-1,1,1").is_err());
        assert!(DeviceMix::parse("0,0,0").is_err());
        // same seed, same tier stream; all three tiers appear under EVAL
        let draws = |seed: u64| -> Vec<ComputeTier> {
            let mut rng = SplitMix64::new(seed);
            (0..64).map(|_| DeviceMix::EVAL.pick(&mut rng)).collect()
        };
        assert_eq!(draws(42), draws(42));
        let d = draws(42);
        for t in ComputeTier::all() {
            assert!(d.contains(&t), "{t:?} missing from EVAL draws");
        }
        assert!(DeviceMix::EVAL.describe().contains("50%"));
    }
}
