//! Edge energy model (DESIGN.md S11, paper RQ5 / Fig. 6).
//!
//! Cellular radios burn most of their energy in the *tail* states that
//! follow every transmission burst (RRC CONNECTED → tail). Cloud-Only
//! decoding streams one round-trip per token, paying the active+tail
//! price per token; FlexSpec batches K tokens per burst, amortizing it.
//! The model tracks compute, radio-active, radio-tail and idle joules
//! separately so Fig. 6's breakdown can be regenerated.

use crate::devices::EdgeDevice;

#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub compute_j: f64,
    pub radio_active_j: f64,
    pub radio_tail_j: f64,
    pub idle_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.radio_active_j + self.radio_tail_j + self.idle_j
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.compute_j += other.compute_j;
        self.radio_active_j += other.radio_active_j;
        self.radio_tail_j += other.radio_tail_j;
        self.idle_j += other.idle_j;
    }
}

/// Per-session energy accounting driven by the pipeline's virtual clock.
#[derive(Debug, Clone)]
pub struct EnergyMeter {
    device: EdgeDevice,
    pub breakdown: EnergyBreakdown,
    /// Virtual time when the current radio tail expires.
    tail_until_ms: f64,
    last_event_ms: f64,
}

impl EnergyMeter {
    pub fn new(device: &EdgeDevice) -> EnergyMeter {
        EnergyMeter {
            device: device.clone(),
            breakdown: EnergyBreakdown::default(),
            tail_until_ms: 0.0,
            last_event_ms: 0.0,
        }
    }

    /// Local compute for `ms` of active drafting/prefill.
    pub fn compute(&mut self, ms: f64) {
        self.breakdown.compute_j += self.device.compute_watts * ms / 1e3;
    }

    /// Radio actively transmitting/receiving for `ms`, ending at virtual
    /// time `end_ms`; restarts the tail window.
    pub fn radio_burst(&mut self, ms: f64, end_ms: f64) {
        self.breakdown.radio_active_j += self.device.radio_active_watts * ms / 1e3;
        // a new burst pre-empts the previous tail: account the part of the
        // old tail that actually elapsed before this burst started.
        let burst_start = end_ms - ms;
        self.settle_tail(burst_start);
        self.tail_until_ms = end_ms + self.device.radio_tail_ms;
        self.last_event_ms = end_ms;
    }

    /// Account tail energy elapsed up to `now_ms`.
    fn settle_tail(&mut self, now_ms: f64) {
        if self.tail_until_ms > self.last_event_ms {
            let tail_end = self.tail_until_ms.min(now_ms);
            let dur = (tail_end - self.last_event_ms).max(0.0);
            self.breakdown.radio_tail_j += self.device.radio_tail_watts * dur / 1e3;
            self.last_event_ms = tail_end.max(self.last_event_ms);
        }
    }

    /// Idle platform draw while waiting (cloud verify, downlink wait).
    pub fn idle(&mut self, ms: f64) {
        self.breakdown.idle_j += self.device.idle_watts * ms / 1e3;
    }

    /// Finalize at end of request: flush any remaining tail.
    pub fn finish(&mut self, now_ms: f64) -> EnergyBreakdown {
        self.settle_tail(now_ms.max(self.tail_until_ms));
        self.breakdown.clone()
    }
}

/// Session energy budget (wire v8 device layer, ROADMAP item 4).
///
/// Tracks how much of an edge session's energy allowance remains so the
/// resource-aware policy can step speculation DOWN as the battery
/// drains. Charging is a pure function of (device, nodes drafted) —
/// deliberately independent of channel noise — so the live edge and the
/// scheduler sim deplete budgets in lockstep and committed sequences
/// stay byte-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyBudget {
    /// Total allowance in joules; 0 = unmetered (never depletes).
    budget_j: f64,
    spent_j: f64,
}

impl EnergyBudget {
    pub fn new(budget_j: f64) -> EnergyBudget {
        assert!(budget_j >= 0.0, "negative energy budget");
        EnergyBudget { budget_j, spent_j: 0.0 }
    }

    /// No metering: `remaining_frac` pins at 1.0 forever.
    pub fn unmetered() -> EnergyBudget {
        EnergyBudget::new(0.0)
    }

    pub fn is_metered(&self) -> bool {
        self.budget_j > 0.0
    }

    /// Draft-compute cost of proposing `n_nodes` tree nodes on `device`
    /// (each alternate leaf costs one extra drafted token).
    pub fn draft_cost_j(device: &EdgeDevice, n_nodes: usize) -> f64 {
        device.compute_watts * n_nodes as f64 * device.draft_ms_per_token / 1e3
    }

    /// Charge one draft proposal of `n_nodes` nodes.
    pub fn charge_draft(&mut self, device: &EdgeDevice, n_nodes: usize) {
        self.charge_j(EnergyBudget::draft_cost_j(device, n_nodes));
    }

    /// Charge an arbitrary number of joules (e.g. radio burst share).
    pub fn charge_j(&mut self, j: f64) {
        self.spent_j += j.max(0.0);
    }

    pub fn remaining_j(&self) -> f64 {
        if self.budget_j <= 0.0 {
            return 0.0;
        }
        (self.budget_j - self.spent_j).max(0.0)
    }

    /// Fraction of the budget left, in [0, 1]; 1.0 when unmetered. This
    /// is the ONLY energy signal the speculation policy reads.
    pub fn remaining_frac(&self) -> f64 {
        if self.budget_j <= 0.0 {
            return 1.0;
        }
        (self.remaining_j() / self.budget_j).clamp(0.0, 1.0)
    }

    pub fn depleted(&self) -> bool {
        self.is_metered() && self.remaining_j() <= 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::SNAPDRAGON_8G3;

    #[test]
    fn compute_energy_is_power_times_time() {
        let mut m = EnergyMeter::new(&SNAPDRAGON_8G3);
        m.compute(1000.0);
        assert!((m.breakdown.compute_j - SNAPDRAGON_8G3.compute_watts).abs() < 1e-9);
    }

    #[test]
    fn tail_follows_burst_and_is_flushed_on_finish() {
        let mut m = EnergyMeter::new(&SNAPDRAGON_8G3);
        m.radio_burst(10.0, 100.0);
        let b = m.finish(100.0 + SNAPDRAGON_8G3.radio_tail_ms + 500.0);
        let expect_tail = SNAPDRAGON_8G3.radio_tail_watts * SNAPDRAGON_8G3.radio_tail_ms / 1e3;
        assert!((b.radio_tail_j - expect_tail).abs() < 1e-9, "{b:?}");
    }

    #[test]
    fn back_to_back_bursts_share_tail() {
        // two bursts 10ms apart: only 10ms of tail between them elapses
        let mut m = EnergyMeter::new(&SNAPDRAGON_8G3);
        m.radio_burst(5.0, 50.0);
        m.radio_burst(5.0, 60.0);
        let b = m.finish(60.0 + SNAPDRAGON_8G3.radio_tail_ms);
        let expect = SNAPDRAGON_8G3.radio_tail_watts * (5.0 + SNAPDRAGON_8G3.radio_tail_ms) / 1e3;
        assert!((b.radio_tail_j - expect).abs() < 1e-6, "{b:?} vs {expect}");
    }

    #[test]
    fn streaming_pays_more_tail_than_bursting() {
        // Fig. 6's mechanism: N small bursts spaced beyond the tail window
        // cost ~N full tails; one big burst costs one tail.
        let dev = &SNAPDRAGON_8G3;
        let mut stream = EnergyMeter::new(dev);
        let mut now = 0.0;
        for _ in 0..10 {
            now += 500.0; // > tail window apart
            stream.radio_burst(2.0, now);
        }
        let s = stream.finish(now + dev.radio_tail_ms);

        let mut burst = EnergyMeter::new(dev);
        burst.radio_burst(20.0, 500.0);
        let b = burst.finish(500.0 + dev.radio_tail_ms);

        assert!(s.radio_tail_j > 5.0 * b.radio_tail_j, "{s:?} vs {b:?}");
        // same active energy (same bytes worth of airtime)
        assert!((s.radio_active_j - b.radio_active_j).abs() < 1e-9);
    }

    #[test]
    fn budget_depletes_monotonically_and_unmetered_never_does() {
        let dev = &SNAPDRAGON_8G3;
        let per_chain = EnergyBudget::draft_cost_j(dev, 4);
        let mut b = EnergyBudget::new(10.0 * per_chain);
        assert!(b.is_metered() && !b.depleted());
        let mut last = b.remaining_frac();
        assert!((last - 1.0).abs() < 1e-12);
        for i in 1..=10 {
            b.charge_draft(dev, 4);
            let f = b.remaining_frac();
            assert!(f < last, "frac must fall each draft (round {i})");
            assert!((f - (1.0 - i as f64 / 10.0)).abs() < 1e-9);
            last = f;
        }
        assert!(b.depleted());
        assert_eq!(b.remaining_j(), 0.0);
        // over-charging clamps, never goes negative
        b.charge_draft(dev, 4);
        assert_eq!(b.remaining_frac(), 0.0);

        let mut u = EnergyBudget::unmetered();
        u.charge_draft(dev, 1_000_000);
        assert!(!u.depleted());
        assert_eq!(u.remaining_frac(), 1.0);
    }

    #[test]
    fn tree_drafts_charge_per_node_not_per_chain() {
        // a comb tree with k=4 chain + 3 alternates costs exactly 7 tokens
        // of draft compute: alternates are not free.
        let dev = &SNAPDRAGON_8G3;
        let chain = EnergyBudget::draft_cost_j(dev, 4);
        let tree = EnergyBudget::draft_cost_j(dev, 7);
        assert!((tree - chain * 7.0 / 4.0).abs() < 1e-12);
        let mut b = EnergyBudget::new(100.0);
        b.charge_draft(dev, 7);
        assert!((b.remaining_j() - (100.0 - tree)).abs() < 1e-12);
        // charging is device-scaled: same nodes on a weaker device cost
        // more joules (slower draft, comparable power)
        let pi = crate::devices::RASPBERRY_PI_5;
        assert!(EnergyBudget::draft_cost_j(&pi, 4) > chain);
    }

    #[test]
    fn totals_add_up() {
        let mut m = EnergyMeter::new(&SNAPDRAGON_8G3);
        m.compute(100.0);
        m.idle(200.0);
        m.radio_burst(10.0, 300.0);
        let b = m.finish(1000.0);
        assert!(
            (b.total_j() - (b.compute_j + b.radio_active_j + b.radio_tail_j + b.idle_j)).abs()
                < 1e-12
        );
    }
}
