//! Fig. 4 — end-to-end latency comparison on GSM8K (bar chart data):
//! the Table III gsm8k rows rendered as per-network bar series with an
//! ASCII bar preview.

use super::{run_cell_default, Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::util::table::Table;
use anyhow::Result;

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    let methods = Method::table_columns();
    let mut t = Table::new(
        "Fig. 4 — GSM8K end-to-end latency per token (Regime A)",
        &["Network", "Method", "ms/token", "Speedup", "bar"],
    );
    for network in NetworkKind::all() {
        let cells: Vec<_> = methods
            .iter()
            .map(|m| run_cell_default(ctx, *m, "gsm8k", network, REGIME_A))
            .collect::<Result<_>>()?;
        let base = cells[0].latency();
        let max = cells.iter().map(|c| c.latency()).fold(0.0, f64::max);
        for (m, c) in methods.iter().zip(&cells) {
            let bar_len = ((c.latency() / max) * 40.0).round() as usize;
            t.row(vec![
                network.label().to_string(),
                m.label().to_string(),
                format!("{:.1}", c.latency()),
                format!("{:.2}x", base / c.latency()),
                "#".repeat(bar_len.max(1)),
            ]);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    #[test]
    fn fig4_rows_cover_grid() {
        let Some(mut ctx) = super::super::test_ctx() else { return };
        ctx.requests = 1;
        let t = &super::run(&ctx).unwrap()[0];
        assert_eq!(t.rows.len(), 3 * 7);
        assert!(t.render().contains("GSM8K"));
    }
}
