//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md per-experiment index). Each experiment returns rendered
//! `Table`s; `report` collects them into EXPERIMENTS-results.md.

pub mod ablations;
pub mod fig2;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table5;
pub mod table6;

use crate::baselines::Method;
use crate::channel::{NetworkKind, NetworkProfile};
use crate::coordinator::{CloudEngine, Pipeline};
use crate::devices::{CloudProfile, EdgeDevice, A800_70B, JETSON_ORIN};
use crate::protocol::VerifyMode;
use crate::runtime::Registry;
use crate::util::stats::Summary;
use crate::util::table::Table;
use anyhow::Result;

/// Shared experiment context.
pub struct Ctx {
    pub reg: Registry,
    /// Requests per (method, dataset, network) cell.
    pub requests: usize,
    pub seed: u64,
    pub verbose: bool,
}

impl Ctx {
    pub fn open(requests: usize, seed: u64) -> Result<Ctx> {
        let reg = Registry::open_default()?;
        crate::workload::corpus::validate_against_manifest(&reg.manifest)?;
        Ok(Ctx {
            reg,
            requests,
            seed,
            verbose: false,
        })
    }
}

/// Aggregated result of one evaluation cell.
#[derive(Debug, Clone, Default)]
pub struct CellStats {
    pub method: String,
    pub ms_per_token: Summary,
    pub acceptance: Summary,
    pub energy_j_per_token: Summary,
    pub bytes_up_per_token: Summary,
    pub mean_k: Summary,
    pub tokens: usize,
}

impl CellStats {
    pub fn latency(&self) -> f64 {
        self.ms_per_token.mean()
    }

    pub fn speedup_vs(&self, baseline: &CellStats) -> f64 {
        baseline.latency() / self.latency()
    }
}

/// Evaluation regime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Regime {
    pub mode: VerifyMode,
    pub temperature: f32,
    pub top_p: f32,
}

pub const REGIME_A: Regime = Regime {
    mode: VerifyMode::Greedy,
    temperature: 0.0,
    top_p: 1.0,
};

pub const REGIME_B: Regime = Regime {
    mode: VerifyMode::Stochastic,
    temperature: 1.0,
    top_p: 0.9,
};

/// Run one (method, dataset, network) cell: `ctx.requests` requests of
/// the dataset against the dataset's evolved target version, identical
/// channel trace and workload across methods (seeded).
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    ctx: &Ctx,
    method: Method,
    family: &str,
    dataset: &str,
    target_version: &str,
    network: NetworkKind,
    regime: Regime,
    device: &EdgeDevice,
    cloud_profile: &CloudProfile,
) -> Result<CellStats> {
    let mut stats = CellStats {
        method: method.label().to_string(),
        ..Default::default()
    };
    let mut gen = crate::workload::WorkloadGen::new(dataset, ctx.seed)?;
    let mut cloud = CloudEngine::new(&ctx.reg, target_version, crate::workload::EOS)?;
    let dom = if dataset == "nq_rag" { "nq" } else { dataset };

    for i in 0..ctx.requests {
        let req = gen.next_request();
        // fresh channel per request, seeded identically across methods
        let mut chan = NetworkProfile::new(network).channel(ctx.seed ^ (i as u64 * 7793 + 11));
        let draft = method.draft_source(&ctx.reg, family, dom)?;
        let policy = method.stride_policy(network);
        let mut pipe = Pipeline::new(
            draft,
            &mut cloud,
            &mut chan,
            policy,
            device,
            cloud_profile,
            regime.mode,
            regime.temperature,
            regime.top_p,
            method.label(),
        )
        .with_wire(method.wire_format());
        let r = pipe.run_request(&req.prompt, req.max_new, ctx.seed ^ (i as u64))?;
        stats.ms_per_token.add(r.ms_per_token());
        if r.drafted > 0 {
            stats.acceptance.add(r.acceptance_rate());
        }
        stats.energy_j_per_token.add(r.energy_per_token_j());
        stats
            .bytes_up_per_token
            .add(r.bytes_up as f64 / r.new_tokens.max(1) as f64);
        if !r.rounds_log.is_empty() {
            stats.mean_k.add(
                r.rounds_log.iter().map(|l| l.k as f64).sum::<f64>() / r.rounds_log.len() as f64,
            );
        }
        stats.tokens += r.new_tokens;
    }
    Ok(stats)
}

/// Convenience: run_cell with the default testbed (Jetson + A800/70B).
pub fn run_cell_default(
    ctx: &Ctx,
    method: Method,
    dataset: &str,
    network: NetworkKind,
    regime: Regime,
) -> Result<CellStats> {
    let target = crate::workload::generator::target_for_dataset("llama2t", dataset);
    run_cell(
        ctx,
        method,
        "llama2t",
        dataset,
        &target,
        network,
        regime,
        &JETSON_ORIN,
        &A800_70B,
    )
}

/// One experiment = name + runner.
pub struct Experiment {
    pub id: &'static str,
    pub title: &'static str,
    pub run: fn(&Ctx) -> Result<Vec<Table>>,
}

pub fn all_experiments() -> Vec<Experiment> {
    vec![
        Experiment { id: "table1", title: "Table I — update-storm sync cost", run: table1::run },
        Experiment { id: "table2", title: "Table II — acceptance collapse under target evolution", run: table2::run },
        Experiment { id: "fig2", title: "Fig. 2 — channel-aware policy landscape", run: fig2::run },
        Experiment { id: "table3", title: "Table III — Regime A (T=0), 6 datasets x 3 networks", run: table3::run_regime_a },
        Experiment { id: "table4", title: "Table IV — Regime B (T=1), 6 datasets x 3 networks", run: table3::run_regime_b },
        Experiment { id: "fig4", title: "Fig. 4 — GSM8K end-to-end latency", run: fig4::run },
        Experiment { id: "fig5", title: "Fig. 5 — fixed vs adaptive stride ablation", run: fig5::run },
        Experiment { id: "table5", title: "Table V — heterogeneous edge devices", run: table5::run },
        Experiment { id: "table6", title: "Table VI — model scalability (Llama-3-like, MoE)", run: table6::run },
        Experiment { id: "fig6", title: "Fig. 6 — energy breakdown", run: fig6::run },
        Experiment { id: "ablations", title: "Ablations — acceptance model, EMA decay, wire format, batching window", run: ablations::run },
    ]
}

pub fn find(id: &str) -> Option<Experiment> {
    all_experiments().into_iter().find(|e| e.id == id)
}

/// Used by several experiments: a Cloud-Only anchor cell for speedups.
pub fn cloud_only_anchor(
    ctx: &Ctx,
    dataset: &str,
    network: NetworkKind,
    regime: Regime,
) -> Result<CellStats> {
    run_cell_default(ctx, Method::CloudOnly, dataset, network, regime)
}

#[cfg(test)]
pub fn test_ctx() -> Option<Ctx> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.json").exists() {
        return None;
    }
    std::env::set_var("FLEXSPEC_ARTIFACTS", root.to_str().unwrap());
    let ctx = Ctx::open(2, 7).ok()?;
    if !ctx.reg.manifest.weights.contains_key("draft_flex_llama2t") {
        return None;
    }
    Some(ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_ids_unique_and_findable() {
        let exps = all_experiments();
        for e in &exps {
            assert!(find(e.id).is_some());
        }
        let mut ids: Vec<_> = exps.iter().map(|e| e.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), exps.len());
    }

    #[test]
    fn run_cell_produces_consistent_stats() {
        let Some(ctx) = test_ctx() else { return };
        let cell = run_cell_default(
            &ctx,
            Method::FlexSpec,
            "gsm8k",
            NetworkKind::FourG,
            REGIME_A,
        )
        .unwrap();
        assert_eq!(cell.ms_per_token.count(), ctx.requests);
        assert!(cell.latency() > 0.0);
        assert!(cell.acceptance.mean() > 0.05, "accept {}", cell.acceptance.mean());
        assert!(cell.tokens > 0);
    }
}
