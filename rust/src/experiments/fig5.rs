//! Fig. 5 — ablation: fixed speculative strides K ∈ {1,3,5,7} vs the
//! channel-aware adaptive policy, GSM8K, all three networks, with the
//! anchor-aligned draft held constant (isolates RQ2).

use super::{run_cell, Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::coordinator::pipeline::StridePolicy;
use crate::coordinator::policy::AdaptivePolicy;
use crate::coordinator::{CloudEngine, Pipeline};
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::channel::NetworkProfile;
use crate::util::stats::Summary;
use crate::util::table::Table;
use anyhow::Result;

fn run_policy_cell(
    ctx: &Ctx,
    policy_for: &dyn Fn() -> StridePolicy,
    network: NetworkKind,
) -> Result<(Summary, Summary)> {
    let mut lat = Summary::new();
    let mut kbar = Summary::new();
    let mut gen = crate::workload::WorkloadGen::new("gsm8k", ctx.seed)?;
    let mut cloud = CloudEngine::new(&ctx.reg, "lora_llama2t_gsm8k", crate::workload::EOS)?;
    for i in 0..ctx.requests {
        let req = gen.next_request();
        let mut chan = NetworkProfile::new(network).channel(ctx.seed ^ (i as u64 * 7793 + 11));
        let draft = Method::FlexSpec.draft_source(&ctx.reg, "llama2t", "gsm8k")?;
        let mut pipe = Pipeline::new(
            draft,
            &mut cloud,
            &mut chan,
            policy_for(),
            &JETSON_ORIN,
            &A800_70B,
            super::REGIME_A.mode,
            super::REGIME_A.temperature,
            super::REGIME_A.top_p,
            "ablation",
        );
        let r = pipe.run_request(&req.prompt, req.max_new, ctx.seed ^ i as u64)?;
        lat.add(r.ms_per_token());
        if !r.rounds_log.is_empty() {
            kbar.add(r.rounds_log.iter().map(|l| l.k as f64).sum::<f64>() / r.rounds_log.len() as f64);
        }
    }
    Ok((lat, kbar))
}

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig. 5 — fixed stride vs channel-aware adaptive (GSM8K, aligned draft)",
        &["Network", "Policy", "ms/token", "p95 ms/token", "mean K used"],
    );
    for network in NetworkKind::all() {
        for k in [1usize, 3, 5, 7] {
            let (lat, kbar) = run_policy_cell(ctx, &|| StridePolicy::Fixed(k), network)?;
            t.row(vec![
                network.label().to_string(),
                format!("Fixed K={k}"),
                format!("{:.1}", lat.mean()),
                format!("{:.1}", lat.p95()),
                format!("{:.1}", kbar.mean()),
            ]);
        }
        let (lat, kbar) = run_policy_cell(
            ctx,
            &|| StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.15)),
            network,
        )?;
        t.row(vec![
            network.label().to_string(),
            "FlexSpec adaptive".to_string(),
            format!("{:.1}", lat.mean()),
            format!("{:.1}", lat.p95()),
            format!("{:.1}", kbar.mean()),
        ]);
    }
    // keep run_cell linked for the anchor (cloud-only reference row)
    let co = run_cell(
        ctx, Method::CloudOnly, "llama2t", "gsm8k", "lora_llama2t_gsm8k",
        NetworkKind::WifiWeak, REGIME_A, &JETSON_ORIN, &A800_70B,
    )?;
    t.row(vec![
        NetworkKind::WifiWeak.label().to_string(),
        "Cloud-Only (ref)".to_string(),
        format!("{:.1}", co.latency()),
        format!("{:.1}", co.ms_per_token.p95()),
        "0.0".to_string(),
    ]);
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_tracks_best_fixed_both_extremes() {
        let Some(mut ctx) = super::super::test_ctx() else { return };
        ctx.requests = 3;
        // 5G: adaptive should be within ~20% of fixed K=5 (the good large stride)
        let (k5_lat, _) = run_policy_cell(&ctx, &|| StridePolicy::Fixed(5), NetworkKind::FiveG).unwrap();
        let (ad_lat, _) = run_policy_cell(
            &ctx,
            &|| StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.15)),
            NetworkKind::FiveG,
        )
        .unwrap();
        assert!(ad_lat.mean() < k5_lat.mean() * 1.25, "5G: {} vs {}", ad_lat.mean(), k5_lat.mean());

        // WiFi: fixed K=5 (stochastic-mode costs charged in regime B only;
        // here greedy) — K=7 must be worse than K=1-ish adaptive behaviour
        let (k7, _) = run_policy_cell(&ctx, &|| StridePolicy::Fixed(7), NetworkKind::WifiWeak).unwrap();
        let (ad_w, kbar) = run_policy_cell(
            &ctx,
            &|| StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.15)),
            NetworkKind::WifiWeak,
        )
        .unwrap();
        assert!(ad_w.mean() <= k7.mean() * 1.1, "wifi: {} vs {}", ad_w.mean(), k7.mean());
        assert!(kbar.mean() > 0.5);
    }
}
