//! Fig. 6 — energy-per-token breakdown on a mobile device (Snapdragon
//! 8 Gen 3, 4G): Cloud-Only streaming vs FlexSpec burst transmission,
//! split into compute / radio-active / radio-tail / idle, plus the
//! memory-footprint comparison of RQ5.

use super::{run_cell, Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::coordinator::{CloudEngine, Pipeline};
use crate::channel::NetworkProfile;
use crate::devices::{A800_70B, SNAPDRAGON_8G3};
use crate::energy::EnergyBreakdown;
use crate::util::table::Table;
use anyhow::Result;

fn energy_cell(ctx: &Ctx, method: Method) -> Result<(EnergyBreakdown, usize)> {
    let mut gen = crate::workload::WorkloadGen::new("mtbench", ctx.seed)?;
    let mut cloud = CloudEngine::new(&ctx.reg, "lora_llama2t_mtbench", crate::workload::EOS)?;
    let mut total = EnergyBreakdown::default();
    let mut tokens = 0usize;
    for i in 0..ctx.requests {
        let req = gen.next_request();
        let mut chan =
            NetworkProfile::new(NetworkKind::FourG).channel(ctx.seed ^ (i as u64 * 7793 + 11));
        let draft = method.draft_source(&ctx.reg, "llama2t", "mtbench")?;
        let mut pipe = Pipeline::new(
            draft,
            &mut cloud,
            &mut chan,
            method.stride_policy(NetworkKind::FourG),
            &SNAPDRAGON_8G3,
            &A800_70B,
            REGIME_A.mode,
            REGIME_A.temperature,
            REGIME_A.top_p,
            method.label(),
        );
        let r = pipe.run_request(&req.prompt, req.max_new, ctx.seed ^ i as u64)?;
        total.add(&r.energy);
        tokens += r.new_tokens;
    }
    Ok((total, tokens))
}

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig. 6 — energy per token on Snapdragon 8 Gen 3, 4G (J/token)",
        &["Method", "Compute", "Radio active", "Radio tail", "Idle", "Total", "vs Cloud-Only"],
    );
    let mut cloud_total = None;
    for method in [Method::CloudOnly, Method::Dssd, Method::FlexSpec] {
        let (e, tokens) = energy_cell(ctx, method)?;
        let per = |j: f64| j / tokens.max(1) as f64;
        let total = per(e.total_j());
        let saving = cloud_total
            .map(|c: f64| format!("-{:.0}%", (1.0 - total / c) * 100.0))
            .unwrap_or_else(|| "—".to_string());
        if cloud_total.is_none() {
            cloud_total = Some(total);
        }
        t.row(vec![
            method.label().to_string(),
            format!("{:.2}", per(e.compute_j)),
            format!("{:.2}", per(e.radio_active_j)),
            format!("{:.2}", per(e.radio_tail_j)),
            format!("{:.2}", per(e.idle_j)),
            format!("{total:.2}"),
            saving,
        ]);
    }

    // RQ5 memory footprint companion table
    let mut t2 = Table::new(
        "RQ5 — edge memory footprint",
        &["Configuration", "Bytes (this repro)", "Paper-scale estimate"],
    );
    let flex = ctx.reg.model("draft_flex_llama2t")?;
    let target = ctx.reg.model("target_llama2t_base")?;
    let ratio = flex.weights.byte_size as f64 / target.weights.byte_size as f64;
    t2.row(vec![
        "FlexSpec draft (anchor + H_small)".into(),
        format!("{:.1} MB", flex.weights.byte_size as f64 / 1e6),
        format!("~{:.1} GB (ratio {ratio:.2} of 4-bit 70B = 42.5 GB)", 42.5 * ratio),
    ]);
    t2.row(vec![
        "Full on-device target (4-bit 70B)".into(),
        format!("{:.1} MB", target.weights.byte_size as f64 / 1e6),
        "42.5 GB (infeasible on phones)".into(),
    ]);
    // keep run_cell referenced for future per-network energy sweeps
    let _ = run_cell;
    Ok(vec![t, t2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flexspec_cuts_radio_energy_majorly() {
        let Some(mut ctx) = super::super::test_ctx() else { return };
        ctx.requests = 2;
        let (cloud, ct) = energy_cell(&ctx, Method::CloudOnly).unwrap();
        let (flex, ft) = energy_cell(&ctx, Method::FlexSpec).unwrap();
        let c_radio = (cloud.radio_active_j + cloud.radio_tail_j) / ct as f64;
        let f_radio = (flex.radio_active_j + flex.radio_tail_j) / ft as f64;
        assert!(
            f_radio < 0.6 * c_radio,
            "radio J/tok: flex {f_radio} vs cloud {c_radio}"
        );
        // paper claims ~53% total reduction; require a substantial cut
        let c_tot = cloud.total_j() / ct as f64;
        let f_tot = flex.total_j() / ft as f64;
        assert!(f_tot < 0.8 * c_tot, "total J/tok {f_tot} vs {c_tot}");
    }
}
