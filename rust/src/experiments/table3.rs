//! Tables III & IV — the main grid: end-to-end latency + speedup for all
//! methods across the six datasets and three networks, in both regimes
//! (A: greedy T=0; B: stochastic T=1, top-p 0.9).

use super::{run_cell_default, CellStats, Ctx, Regime, REGIME_A, REGIME_B};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::util::table::{latency_cell, Table};
use crate::workload::generator::EVAL_DATASETS;
use anyhow::Result;

pub fn run_regime_a(ctx: &Ctx) -> Result<Vec<Table>> {
    run_grid(ctx, REGIME_A, "Table III — Regime A (Temperature = 0)")
}

pub fn run_regime_b(ctx: &Ctx) -> Result<Vec<Table>> {
    run_grid(ctx, REGIME_B, "Table IV — Regime B (Temperature = 1, top-p 0.9)")
}

pub fn run_grid(ctx: &Ctx, regime: Regime, title: &str) -> Result<Vec<Table>> {
    let methods = Method::table_columns();
    let mut headers: Vec<&str> = vec!["Dataset", "Network"];
    let labels: Vec<String> = methods.iter().map(|m| m.label().to_string()).collect();
    for l in &labels {
        headers.push(l);
    }
    let mut t = Table::new(title, &headers);

    // "Sync Required?" header row, as in the paper
    let mut sync_row = vec!["Sync Required?".to_string(), String::new()];
    for m in &methods {
        sync_row.push(if m.sync_required() { "Yes" } else { "No" }.to_string());
    }
    t.row(sync_row);

    for (dataset, ds_label) in EVAL_DATASETS {
        for network in NetworkKind::all() {
            let mut cells: Vec<CellStats> = Vec::new();
            for m in methods {
                cells.push(run_cell_default(ctx, m, dataset, network, regime)?);
                if ctx.verbose {
                    eprintln!(
                        "[table] {ds_label} {} {}: {:.1} ms/tok",
                        network.label(),
                        m.label(),
                        cells.last().unwrap().latency()
                    );
                }
            }
            let base = cells[0].latency();
            let mut row = vec![ds_label.to_string(), network.label().to_string()];
            for c in &cells {
                row.push(latency_cell(c.latency(), base / c.latency()));
            }
            t.row(row);
        }
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Method;
    use crate::channel::NetworkKind;
    use crate::experiments::{run_cell_default, REGIME_A, REGIME_B};

    /// The qualitative SHAPE claims of Tables III/IV on the headline
    /// dataset (gsm8k): who wins where. Full-grid rendering is covered by
    /// the bench harness; here we pin the crossovers cheaply.
    #[test]
    fn regime_a_shape_gsm8k() {
        let Some(ctx) = super::super::test_ctx() else { return };

        // 5G: synced EAGLE-2 is the best; FlexSpec close behind; all beat cloud-only
        let co = run_cell_default(&ctx, Method::CloudOnly, "gsm8k", NetworkKind::FiveG, REGIME_A).unwrap();
        let eagle = run_cell_default(&ctx, Method::Eagle2, "gsm8k", NetworkKind::FiveG, REGIME_A).unwrap();
        let flex = run_cell_default(&ctx, Method::FlexSpec, "gsm8k", NetworkKind::FiveG, REGIME_A).unwrap();
        assert!(eagle.latency() < co.latency());
        assert!(flex.latency() < co.latency());
        assert!(eagle.latency() < flex.latency() * 1.15, "ideal-synced wins 5G");

        // WiFi: Std SD collapses below 1x; FlexSpec stays the best method
        let co_w = run_cell_default(&ctx, Method::CloudOnly, "gsm8k", NetworkKind::WifiWeak, REGIME_A).unwrap();
        let std_w = run_cell_default(&ctx, Method::StdSd, "gsm8k", NetworkKind::WifiWeak, REGIME_A).unwrap();
        let flex_w = run_cell_default(&ctx, Method::FlexSpec, "gsm8k", NetworkKind::WifiWeak, REGIME_A).unwrap();
        let eagle_w = run_cell_default(&ctx, Method::Eagle2, "gsm8k", NetworkKind::WifiWeak, REGIME_A).unwrap();
        assert!(std_w.latency() > co_w.latency(), "Std SD must collapse on weak WiFi");
        assert!(flex_w.latency() < co_w.latency(), "FlexSpec must still accelerate");
        assert!(flex_w.latency() < eagle_w.latency(), "fixed-stride synced methods lose weak nets");
    }

    #[test]
    fn regime_b_flexspec_stays_robust() {
        let Some(ctx) = super::super::test_ctx() else { return };
        let co = run_cell_default(&ctx, Method::CloudOnly, "gsm8k", NetworkKind::FourG, REGIME_B).unwrap();
        let flex = run_cell_default(&ctx, Method::FlexSpec, "gsm8k", NetworkKind::FourG, REGIME_B).unwrap();
        let speedup = co.latency() / flex.latency();
        assert!(speedup > 1.2, "Regime B 4G speedup {speedup}");
    }
}
