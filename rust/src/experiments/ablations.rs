//! Design-choice ablations beyond the paper's Fig. 5 (DESIGN.md §Perf):
//!
//!  A1 — acceptance model: linear EMA (paper's Algorithm-2 approximation)
//!       vs geometric (our default; see policy.rs for why linear
//!       degenerates to boundary K*).
//!  A2 — gamma-hat EMA decay mu: adaptation speed vs stability.
//!  A3 — wire format for FlexSpec itself: compact indices (the paper's
//!       design) vs shipping the full sketch (what tightly-coupled
//!       baselines pay).
//!  A4 — verification batching window (multi-user serving).

use super::{Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::{NetworkKind, NetworkProfile};
use crate::coordinator::policy::{AcceptanceModel, AdaptivePolicy};
use crate::coordinator::{serve, CloudEngine, Pipeline, ServeConfig, StridePolicy};
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::metrics::MetricsSet;
use crate::protocol::WireFormat;
use crate::util::table::Table;
use anyhow::Result;

fn flex_cell(
    ctx: &Ctx,
    label: &str,
    network: NetworkKind,
    policy: &dyn Fn() -> StridePolicy,
    wire: WireFormat,
    set: &mut MetricsSet,
) -> Result<()> {
    let mut gen = crate::workload::WorkloadGen::new("gsm8k", ctx.seed)?;
    let mut cloud = CloudEngine::new(&ctx.reg, "lora_llama2t_gsm8k", crate::workload::EOS)?;
    for i in 0..ctx.requests {
        let req = gen.next_request();
        let mut chan = NetworkProfile::new(network).channel(ctx.seed ^ (i as u64 * 7793 + 11));
        let mut pipe = Pipeline::new(
            Method::FlexSpec.draft_source(&ctx.reg, "llama2t", "gsm8k")?,
            &mut cloud,
            &mut chan,
            policy(),
            &JETSON_ORIN,
            &A800_70B,
            REGIME_A.mode,
            REGIME_A.temperature,
            REGIME_A.top_p,
            label,
        )
        .with_wire(wire);
        let r = pipe.run_request(&req.prompt, req.max_new, ctx.seed ^ i as u64)?;
        set.record(&r);
    }
    Ok(())
}

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    let mut tables = Vec::new();

    // A1: acceptance model, weak WiFi (where K* choice matters most)
    let mut set = MetricsSet::default();
    for (label, model) in [
        ("geometric (default)", AcceptanceModel::Geometric),
        ("linear (paper eq. approx)", AcceptanceModel::Linear),
    ] {
        flex_cell(
            ctx, label, NetworkKind::WifiWeak,
            &|| StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.15).with_model(model)),
            WireFormat::Compact, &mut set,
        )?;
    }
    tables.push(set.table("Ablation A1 — E[tau|K] model (GSM8K, weak WiFi)", None));

    // A2: EMA decay mu
    let mut set = MetricsSet::default();
    for mu in [0.05, 0.15, 0.5] {
        flex_cell(
            ctx, &format!("mu={mu}"), NetworkKind::WifiWeak,
            &|| StridePolicy::Adaptive(AdaptivePolicy::new(8, mu)),
            WireFormat::Compact, &mut set,
        )?;
    }
    tables.push(set.table("Ablation A2 — gamma-hat EMA decay (GSM8K, weak WiFi)", None));

    // A3: FlexSpec wire format
    let mut set = MetricsSet::default();
    for (label, wire) in [
        ("compact indices (paper design)", WireFormat::Compact),
        ("full sketch (baseline wire)", WireFormat::Sketch),
    ] {
        flex_cell(
            ctx, label, NetworkKind::WifiWeak,
            &|| StridePolicy::Adaptive(AdaptivePolicy::new(8, 0.15)),
            wire, &mut set,
        )?;
    }
    tables.push(set.table("Ablation A3 — FlexSpec uplink format (GSM8K, weak WiFi)", None));

    // A4: verification batching window (multi-user serving)
    let mut t = Table::new(
        "Ablation A4 — verification batching window (6 users, 5G, mtbench)",
        &["window (ms)", "mean batch", "throughput tok/s", "p95 request ms", "T_base saved ms"],
    );
    let draft = ctx.reg.model("draft_flex_llama2t")?;
    let mut gen = crate::workload::WorkloadGen::new("mtbench", ctx.seed)?;
    let prompts: Vec<Vec<i32>> = gen.take(6).into_iter().map(|r| r.prompt).collect();
    for window in [0.01, 12.0, 60.0] {
        let mut cloud = CloudEngine::new(&ctx.reg, "lora_llama2t_mtbench", crate::workload::EOS)?;
        let cfg = ServeConfig {
            users: 6,
            max_new: 16,
            window_ms: window,
            arrival_mean_ms: 5.0,
            seed: ctx.seed,
            ..Default::default()
        };
        let rep = serve(
            &mut cloud, draft.clone(), &prompts, &JETSON_ORIN, &A800_70B,
            &NetworkProfile::new(NetworkKind::FiveG), &cfg,
        )?;
        t.row(vec![
            format!("{window}"),
            format!("{:.2}", rep.mean_batch),
            format!("{:.1}", rep.throughput_tok_s()),
            format!("{:.0}", rep.request_latency.p95()),
            format!("{:.0}", rep.t_base_saved_ms),
        ]);
    }
    tables.push(t);
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_produce_all_tables() {
        let Some(mut ctx) = super::super::test_ctx() else { return };
        ctx.requests = 1;
        let tables = run(&ctx).unwrap();
        assert_eq!(tables.len(), 4);
        assert_eq!(tables[0].rows.len(), 2);
        assert_eq!(tables[1].rows.len(), 3);
        // A3: compact wire must beat the sketch wire on weak WiFi
        let parse = |s: &str| s.parse::<f64>().unwrap();
        let compact = parse(&tables[2].rows[0][1]);
        let sketch = parse(&tables[2].rows[1][1]);
        assert!(compact < sketch, "compact {compact} vs sketch {sketch}");
    }
}
