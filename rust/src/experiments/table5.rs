//! Table V — FlexSpec on heterogeneous edge devices under 4G (speedup vs
//! Cloud-Only): the Pi-5 CPU lower bound, NPU phones, and Jetson across
//! GSM8K / MT-Bench / HumanEval.

use super::{run_cell, Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::devices::{all_edge_devices, A800_70B};
use crate::util::table::Table;
use crate::workload::generator::target_for_dataset;
use anyhow::Result;

const TASKS: &[(&str, &str)] = &[
    ("gsm8k", "GSM8K (Hard)"),
    ("mtbench", "MT-Bench (Med)"),
    ("humaneval", "HumanEval (Hard)"),
];

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    let mut headers = vec!["Device", "Processor", "Draft ms/tok", "Draft tok/s"];
    for (_, label) in TASKS {
        headers.push(label);
    }
    let mut t = Table::new(
        "Table V — FlexSpec on heterogeneous edge devices, 4G (speedup vs Cloud-Only)",
        &headers,
    );
    for dev in all_edge_devices() {
        let mut row = vec![
            dev.name.to_string(),
            dev.processor.to_string(),
            format!("{:.1}", dev.draft_ms_per_token),
            format!("{:.1}", dev.draft_throughput_tps()),
        ];
        for (dataset, _) in TASKS {
            let target = target_for_dataset("llama2t", dataset);
            let co = run_cell(
                ctx, Method::CloudOnly, "llama2t", dataset, &target,
                NetworkKind::FourG, REGIME_A, dev, &A800_70B,
            )?;
            let fs = run_cell(
                ctx, Method::FlexSpec, "llama2t", dataset, &target,
                NetworkKind::FourG, REGIME_A, dev, &A800_70B,
            )?;
            let speedup = co.latency() / fs.latency();
            row.push(if speedup < 1.0 {
                format!("{speedup:.2}x (Slowdown)")
            } else {
                format!("{speedup:.2}x")
            });
        }
        t.row(row);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::{JETSON_ORIN, RASPBERRY_PI_5};

    #[test]
    fn pi5_slows_down_jetson_speeds_up() {
        let Some(ctx) = super::super::test_ctx() else { return };
        let target = target_for_dataset("llama2t", "gsm8k");
        let co = run_cell(
            &ctx, Method::CloudOnly, "llama2t", "gsm8k", &target,
            NetworkKind::FourG, REGIME_A, &RASPBERRY_PI_5, &A800_70B,
        )
        .unwrap();
        let pi = run_cell(
            &ctx, Method::FlexSpec, "llama2t", "gsm8k", &target,
            NetworkKind::FourG, REGIME_A, &RASPBERRY_PI_5, &A800_70B,
        )
        .unwrap();
        // the paper's hardware lower bound: CPU drafting at 6.9 tok/s
        // makes FlexSpec a net slowdown
        assert!(pi.latency() > co.latency() * 0.95, "pi {} vs co {}", pi.latency(), co.latency());

        let co_j = run_cell(
            &ctx, Method::CloudOnly, "llama2t", "gsm8k", &target,
            NetworkKind::FourG, REGIME_A, &JETSON_ORIN, &A800_70B,
        )
        .unwrap();
        let jet = run_cell(
            &ctx, Method::FlexSpec, "llama2t", "gsm8k", &target,
            NetworkKind::FourG, REGIME_A, &JETSON_ORIN, &A800_70B,
        )
        .unwrap();
        assert!(co_j.latency() / jet.latency() > 1.3, "jetson speedup");
    }
}
