//! Table VI — scalability to newer model families: the Llama-3-like
//! (bigger vocab) and Mixtral-like (MoE) targets on MT-Bench, 5G/4G.

use super::{run_cell, Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::devices::{CloudProfile, A800_70B, CLOUD_LLAMA3, CLOUD_MIXTRAL, JETSON_ORIN};
use crate::util::table::Table;
use anyhow::Result;

const FAMILIES: &[(&str, &str, &str, &CloudProfile)] = &[
    ("llama2t", "Llama-2-70B (dense)", "lora_llama2t_mtbench", &A800_70B),
    ("llama3t", "Llama-3-70B (dense)", "lora_llama3t_mtbench", &CLOUD_LLAMA3),
    ("mixtralt", "Mixtral 8x7B (MoE)", "lora_mixtralt_mtbench", &CLOUD_MIXTRAL),
];

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table VI — scalability across model families (MT-Bench)",
        &["Target Model", "Arch.", "Baseline ms/tok (5G/4G)", "FlexSpec (5G)", "FlexSpec (4G)", "accept"],
    );
    for (family, label, target, cloud) in FAMILIES {
        if !ctx.reg.manifest.weights.contains_key(*target) {
            continue; // family not built yet
        }
        let mut cells = Vec::new();
        for network in [NetworkKind::FiveG, NetworkKind::FourG] {
            let co = run_cell(
                ctx, Method::CloudOnly, family, "mtbench", target,
                network, REGIME_A, &JETSON_ORIN, cloud,
            )?;
            let fs = run_cell(
                ctx, Method::FlexSpec, family, "mtbench", target,
                network, REGIME_A, &JETSON_ORIN, cloud,
            )?;
            cells.push((co, fs));
        }
        let arch = if family.contains("mixtral") { "MoE" } else { "Dense" };
        t.row(vec![
            label.to_string(),
            arch.to_string(),
            format!("{:.1} / {:.1}", cells[0].0.latency(), cells[1].0.latency()),
            format!("{:.2}x", cells[0].0.latency() / cells[0].1.latency()),
            format!("{:.2}x", cells[1].0.latency() / cells[1].1.latency()),
            format!("{:.2}", cells[0].1.acceptance.mean()),
        ]);
    }
    Ok(vec![t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_built_family() {
        let Some(mut ctx) = super::super::test_ctx() else { return };
        ctx.requests = 1;
        let t = &run(&ctx).unwrap()[0];
        assert!(!t.rows.is_empty());
        // llama2t is always built; others appear once their artifacts exist
        assert!(t.rows.iter().any(|r| r[0].contains("Llama-2")));
        for row in &t.rows {
            let s5: f64 = row[3].trim_end_matches('x').parse().unwrap();
            assert!(s5 > 0.8, "family {} speedup {s5}", row[0]);
        }
    }
}
