//! Table I — estimated latency for synchronizing draft models over
//! wireless networks, plus fleet scalability and what each method ships
//! per cloud update.

use super::Ctx;
use crate::channel::NetworkKind;
use crate::coordinator::sync::{self, DRAFT_MODEL_BYTES};
use crate::util::table::Table;
use anyhow::Result;

pub fn run(_ctx: &Ctx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Table I — draft-model synchronization over wireless networks (3.2 GB draft)",
        &["Network Type", "Bandwidth", "Sync Time (one user)", "Scalability (1k users)", "Fleet traffic"],
    );
    for kind in NetworkKind::all() {
        let one = sync::sync_cost(kind, 1, DRAFT_MODEL_BYTES);
        let fleet = sync::sync_cost(kind, 1000, DRAFT_MODEL_BYTES);
        t.row(vec![
            kind.label().to_string(),
            one.bandwidth_label.clone(),
            format!("{:.1} min", one.one_user_minutes),
            fleet.scalability.to_string(),
            format!("{:.1} TB", fleet.fleet_bytes as f64 / 1e12),
        ]);
    }

    let mut t2 = Table::new(
        "Table I (cont.) — update traffic shipped per cloud model update",
        &["Method", "Sync required?", "Bytes/update/user"],
    );
    for key in ["flexspec", "eagle2", "medusa", "std_sd", "pld"] {
        let u = sync::method_update_traffic(key);
        t2.row(vec![
            u.method.to_string(),
            if u.sync_required { "Yes" } else { "No" }.to_string(),
            if u.bytes_per_update_per_user == 0 {
                "0".to_string()
            } else {
                format!("{:.1} GB", u.bytes_per_update_per_user as f64 / 1e9)
            },
        ]);
    }
    Ok(vec![t, t2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_runs_without_artifacts() {
        // analytic — must work even before `make artifacts`
        let fake = Ctx {
            reg: match crate::runtime::Registry::open_default() {
                Ok(r) => r,
                Err(_) => return, // registry needed only for the Ctx shape
            },
            requests: 1,
            seed: 1,
            verbose: false,
        };
        let tables = run(&fake).unwrap();
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 3);
        let rendered = tables[0].render();
        assert!(rendered.contains("WiFi"));
    }
}
