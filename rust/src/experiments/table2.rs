//! Table II — impact of target-model evolution on a fixed draft model:
//! the "performance collapse" motivation experiment, measured end-to-end
//! through the real pipeline (acceptance of the generic frozen draft
//! against Base / Math-LoRA / Code-Full target versions), extended with
//! the FlexSpec anchor-aligned draft rows that explain the fix.

use super::{run_cell, Ctx, REGIME_A};
use crate::baselines::Method;
use crate::channel::NetworkKind;
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::util::table::Table;
use anyhow::Result;

pub fn run(ctx: &Ctx) -> Result<Vec<Table>> {
    // (target version, domain prompts to evaluate on, label, domain col)
    let cases: &[(&str, &str, &str, &str)] = &[
        ("target_llama2t_base", "general", "Base", "General"),
        ("lora_llama2t_gsm8k", "gsm8k", "Math (LoRA)", "Mathematics"),
        ("target_llama2t_code_full", "humaneval", "Code (Full)", "Programming"),
    ];

    let mut t = Table::new(
        "Table II — acceptance rate of a FIXED generic draft vs evolving targets",
        &["Target Model Version", "Domain", "Acceptance (Std. SD)", "Acceptance (FlexSpec draft)"],
    );
    let mut base_generic = None;
    for (version, dataset, label, domain_label) in cases {
        let generic = run_cell(
            ctx, Method::StdSd, "llama2t", dataset, version,
            NetworkKind::FourG, REGIME_A, &JETSON_ORIN, &A800_70B,
        )?;
        let flex = run_cell(
            ctx, Method::FlexSpec, "llama2t", dataset, version,
            NetworkKind::FourG, REGIME_A, &JETSON_ORIN, &A800_70B,
        )?;
        let g = generic.acceptance.mean();
        let f = flex.acceptance.mean();
        let drop = base_generic
            .map(|b: f64| format!("{:.2} (-{:.0}%)", g, (1.0 - g / b) * 100.0))
            .unwrap_or_else(|| format!("{g:.2}"));
        if base_generic.is_none() {
            base_generic = Some(g);
        }
        t.row(vec![
            format!("Llama-2t-{label}"),
            domain_label.to_string(),
            drop,
            format!("{f:.2}"),
        ]);
    }

    // cross-check against the build-time python calibration if present
    let mut t2 = Table::new(
        "Table II cross-check — build-time python calibration (manifest)",
        &["pair", "acceptance"],
    );
    for (k, v) in &ctx.reg.manifest.calibration {
        t2.row(vec![k.clone(), format!("{v:.3}")]);
    }
    Ok(vec![t, t2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collapse_gradient_reproduces() {
        let Some(ctx) = super::super::test_ctx() else { return };
        if !ctx
            .reg
            .manifest
            .weights
            .contains_key("target_llama2t_code_full")
        {
            return;
        }
        let tables = run(&ctx).unwrap();
        let t = &tables[0];
        assert_eq!(t.rows.len(), 3);
        // generic acceptance must fall monotonically base -> math -> code
        let parse = |s: &str| s.split_whitespace().next().unwrap().parse::<f64>().unwrap();
        let base = parse(&t.rows[0][2]);
        let math = parse(&t.rows[1][2]);
        let code = parse(&t.rows[2][2]);
        assert!(base > math && math > code, "collapse gradient: {base} {math} {code}");
        // flex draft must hold up far better on the LoRA-evolved target
        let flex_math = t.rows[1][3].parse::<f64>().unwrap();
        assert!(flex_math > math, "anchor alignment fix: {flex_math} vs {math}");
    }
}
