//! Fig. 2 — the channel-aware policy landscape: T_step(K) and ETGR(K)
//! across signal regimes, and where K* lands. Analytic over the latency
//! model (eq. 10/11) with the geometric acceptance model (see
//! policy.rs on why the linear EMA approximation degenerates).
//!
//! Regimes: "Weak (SNR<5dB)" is the deep-fade state of the weak-WiFi
//! channel (rate/8, propagation x2.5 — elevator/subway) at the
//! post-evolution acceptance gamma=0.6 FlexSpec actually operates at;
//! Medium = typical 4G (gamma 0.7); Strong = 5G (gamma 0.8).

use super::Ctx;
use crate::channel::ChannelState;
use crate::coordinator::policy::{etgr, AcceptanceModel, AdaptivePolicy, LatencyModel};
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::protocol::WireFormat;
use crate::util::table::Table;
use anyhow::Result;

struct SignalRegime {
    label: &'static str,
    up_mbps: f64,
    prop_ms: f64,
    gamma: f64,
    loss: f64,
}

const REGIMES: &[SignalRegime] = &[
    SignalRegime { label: "Weak (SNR<5dB fade)", up_mbps: 1.5 / 8.0, prop_ms: 450.0, gamma: 0.6, loss: 0.25 },
    SignalRegime { label: "Medium (4G)", up_mbps: 50.0, prop_ms: 95.0, gamma: 0.7, loss: 0.008 },
    SignalRegime { label: "Strong (5G)", up_mbps: 300.0, prop_ms: 18.0, gamma: 0.8, loss: 0.001 },
];

pub fn run(_ctx: &Ctx) -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig. 2 — per-round latency T_step(K) and ETGR(K) by signal strength",
        &["Signal", "K", "T_step (ms)", "ETGR (tok/s)", "K*?"],
    );
    let mut kstars = Vec::new();
    for r in REGIMES {
        let chan = ChannelState {
            up_bps: r.up_mbps * 1e6,
            down_bps: r.up_mbps * 2e6,
            prop_ms: r.prop_ms,
            fading: false,
            loss_rate: r.loss,
        };
        let lat = LatencyModel::build(&chan, &JETSON_ORIN, &A800_70B, WireFormat::Sketch);
        let mut policy = AdaptivePolicy::new(8, 0.1);
        policy.gamma = crate::util::stats::Ema::new(r.gamma, 0.1);
        let kstar = policy.select_k(&lat);
        kstars.push((r.label, kstar));
        for k in 1..=8usize {
            t.row(vec![
                r.label.to_string(),
                k.to_string(),
                format!("{:.1}", lat.step_ms(k)),
                format!("{:.2}", etgr(AcceptanceModel::Geometric, r.gamma, &lat, k) * 1e3),
                if k == kstar { "<-- K*".into() } else { String::new() },
            ]);
        }
    }

    let mut t2 = Table::new(
        "Fig. 2 (headline) — optimal stride shifts with signal strength",
        &["Signal", "K*"],
    );
    for (label, k) in kstars {
        t2.row(vec![label.to_string(), k.to_string()]);
    }
    Ok(vec![t2, t])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kstar_shifts_weak_to_strong() {
        let Some(ctx) = super::super::test_ctx() else { return };
        let tables = run(&ctx).unwrap();
        let head = &tables[0];
        let weak: usize = head.rows[0][1].parse().unwrap();
        let medium: usize = head.rows[1][1].parse().unwrap();
        let strong: usize = head.rows[2][1].parse().unwrap();
        // paper: K* ~2 weak, ~6 strong
        assert!(weak <= 3, "weak K*={weak}");
        assert!(strong >= 6, "strong K*={strong}");
        assert!(weak < medium && medium <= strong);
    }
}
