fn main() -> anyhow::Result<()> {
    flexspec::cli_main()
}
