//! Summary statistics, percentiles and fixed-bucket histograms used by
//! the metrics layer and the experiment harness.

/// Online mean/variance (Welford) + retained samples for percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            self.m2 / (self.samples.len() - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_sorted(&sorted, q)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 0 {
        return f64::NAN;
    }
    if n == 1 {
        return sorted[0];
    }
    let rank = (q / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(n - 1)] * frac
}

/// Exponential moving average — the paper's gamma-hat estimator
/// (Algorithm 2, step 3): `g <- (1-mu)*g + mu*x`.
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    value: f64,
    mu: f64,
}

impl Ema {
    pub fn new(initial: f64, mu: f64) -> Self {
        Self { value: initial, mu }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        self.value = (1.0 - self.mu) * self.value + self.mu * x;
        self.value
    }

    pub fn get(&self) -> f64 {
        self.value
    }
}

/// Fixed-width histogram over [lo, hi) with overflow/underflow buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n: usize) -> Self {
        assert!(hi > lo && n > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; n],
            under: 0,
            over: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.under + self.over
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Compact sparkline rendering for log output.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.buckets.iter().copied().max().unwrap_or(0).max(1);
        self.buckets
            .iter()
            .map(|&b| BARS[(b * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.count(), 4);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::new();
        s.extend((1..=100).map(|i| i as f64));
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p95() - 95.05).abs() < 1e-9);
    }

    #[test]
    fn percentile_of_singleton_and_empty() {
        let mut s = Summary::new();
        assert!(s.p50().is_nan());
        s.add(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn ema_matches_paper_update() {
        // gamma <- (1-mu)*gamma + mu*(tau/K), initial 0.8 (Algorithm 2)
        let mut g = Ema::new(0.8, 0.1);
        g.update(0.5);
        assert!((g.get() - (0.9 * 0.8 + 0.1 * 0.5)).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_bounds() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [-1.0, 0.0, 0.5, 5.0, 9.999, 10.0, 42.0] {
            h.add(x);
        }
        assert_eq!(h.total(), 7);
        assert_eq!(h.buckets()[0], 2); // 0.0 and 0.5
        assert_eq!(h.buckets()[5], 1);
        assert_eq!(h.buckets()[9], 1);
        assert_eq!(h.sparkline().chars().count(), 10);
    }
}
