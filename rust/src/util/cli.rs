//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Subcommand dispatch is done by the caller on `positional(0)`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `value_opts` lists the option names that consume a value; anything
    /// else starting with `--` is a boolean flag.
    pub fn parse(raw: impl IntoIterator<Item = String>, value_opts: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if value_opts.contains(&body) {
                    match it.next() {
                        Some(v) => {
                            out.options.insert(body.to_string(), v);
                        }
                        None => {
                            out.flags.push(body.to_string());
                        }
                    }
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(value_opts: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], value_opts: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), value_opts)
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse(&["exp", "table3", "--verbose"], &[]);
        assert_eq!(a.positional(0), Some("exp"));
        assert_eq!(a.positional(1), Some("table3"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn options_with_space_and_equals() {
        let a = parse(
            &["--requests", "40", "--network=wifi", "--seed=7"],
            &["requests"],
        );
        assert_eq!(a.get_usize("requests", 0), 40);
        assert_eq!(a.get("network"), Some("wifi"));
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_or("x", "dflt"), "dflt");
        assert_eq!(a.get_f64("r", 1.5), 1.5);
    }

    #[test]
    fn unparseable_value_falls_back() {
        let a = parse(&["--n=abc"], &[]);
        assert_eq!(a.get_usize("n", 9), 9);
    }

    #[test]
    fn value_opt_at_end_degrades_to_flag() {
        let a = parse(&["--requests"], &["requests"]);
        assert!(a.flag("requests"));
    }
}
