//! Micro-benchmark harness (criterion is not available offline).
//!
//! Warmup + timed iterations with mean/p50/p95 reporting and a
//! `black_box` to defeat constant folding. Used by rust/benches/*.

use std::time::Instant;

pub use std::hint::black_box;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("iters".into(), Json::Num(self.iters as f64)),
            ("mean_ns".into(), Json::Num(self.mean_ns)),
            ("p50_ns".into(), Json::Num(self.p50_ns)),
            ("p95_ns".into(), Json::Num(self.p95_ns)),
            ("min_ns".into(), Json::Num(self.min_ns)),
        ])
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10}  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            format!("{} it", self.iters),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to roughly `budget_ms` of
/// wall time (min 10 iterations), after `warmup` iterations.
pub fn bench<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    let mut calib_iters = 0usize;
    while t0.elapsed().as_secs_f64() < 0.02 || calib_iters < 3 {
        f();
        calib_iters += 1;
        if calib_iters > 1000 {
            break;
        }
    }
    let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
    let iters = ((budget_ms / 1e3 / per_iter.max(1e-9)) as usize).clamp(10, 100_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let s = Instant::now();
        f();
        samples.push(s.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: mean,
        p50_ns: crate::util::stats::percentile_sorted(&samples, 50.0),
        p95_ns: crate::util::stats::percentile_sorted(&samples, 95.0),
        min_ns: samples[0],
    }
}

/// Run + print a group of benches; returns results for programmatic use.
pub struct Group {
    pub name: String,
    pub results: Vec<BenchResult>,
    budget_ms: f64,
}

impl Group {
    pub fn new(name: &str) -> Self {
        println!("\n### bench group: {name}");
        Self {
            name: name.to_string(),
            results: Vec::new(),
            budget_ms: 300.0,
        }
    }

    pub fn with_budget(mut self, ms: f64) -> Self {
        self.budget_ms = ms;
        self
    }

    pub fn add<F: FnMut()>(&mut self, name: &str, f: F) -> &mut Self {
        let r = bench(name, self.budget_ms, f);
        println!("{}", r.report());
        self.results.push(r);
        self
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(vec![
            ("group".into(), Json::Str(self.name.clone())),
            (
                "results".into(),
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// Write a machine-readable report of the given groups when the
/// `FLEXSPEC_BENCH_JSON` env var names a path (CI uploads it as an
/// artifact so bench trajectories survive the run). No-op otherwise.
pub fn maybe_write_json_report(groups: &[&Group]) -> std::io::Result<()> {
    let Some(path) = std::env::var_os("FLEXSPEC_BENCH_JSON") else {
        return Ok(());
    };
    let path = std::path::PathBuf::from(path);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let json =
        crate::util::json::Json::Arr(groups.iter().map(|g| g.to_json()).collect());
    std::fs::write(&path, json.to_string_pretty())?;
    println!("\nwrote bench report to {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut x = 0u64;
        let r = bench("noop-ish", 10.0, || {
            x = black_box(x.wrapping_add(1));
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.min_ns <= r.p50_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
