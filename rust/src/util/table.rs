//! Plain-text / markdown table rendering for the experiment reports —
//! the harness prints the same rows the paper's tables show.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Aligned plain-text rendering (for terminal output).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1))));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-markdown rendering (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a latency + speedup cell the way the paper's tables do:
/// `"625.0ms (1.95x)"`.
pub fn latency_cell(ms: f64, speedup: f64) -> String {
    format!("{ms:.1}ms ({speedup:.2}x)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["method", "ms"]);
        t.row(vec!["cloud-only".into(), "432.0".into()]);
        t.row(vec!["flexspec".into(), "220.0".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("cloud-only  432.0"));
    }

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("m", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        Table::new("t", &["a"]).row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn latency_cell_format() {
        assert_eq!(latency_cell(625.0, 1.952), "625.0ms (1.95x)");
    }
}
