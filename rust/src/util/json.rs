//! Minimal JSON parser/serializer (serde is not available offline).
//!
//! Covers the full JSON grammar we produce and consume: manifest.json from
//! the python build pipeline, experiment configs, and metric dumps.
//! Numbers are f64 (like javascript); object keys keep insertion order via
//! a Vec of pairs so serialized output is stable.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------------
    // accessors
    // ---------------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the path name — for manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn obj_to_map(&self) -> BTreeMap<String, &Json> {
        match self {
            Json::Obj(o) => o.iter().map(|(k, v)| (k.clone(), v)).collect(),
            _ => BTreeMap::new(),
        }
    }

    // ---------------------------------------------------------------
    // builders
    // ---------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---------------------------------------------------------------
    // parse
    // ---------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------------------------------------------------------
    // serialize
    // ---------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one utf-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,"s",true,null],"y":{"z":[]}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn roundtrip_pretty() {
        let j = Json::obj(vec![
            ("k", Json::num(1.5)),
            ("arr", Json::Arr(vec![Json::num(1), Json::str("two")])),
        ]);
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""é\t\"q\"""#).unwrap();
        assert_eq!(j.as_str(), Some("é\t\"q\""));
        let s = Json::Str("é\t\"q\"".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("é\t\"q\""));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.25).to_string(), "3.25");
    }

    #[test]
    fn errors_carry_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(text) = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/manifest.json"
        )) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("archs").is_some());
        }
    }
}
