//! Mini property-based testing helper (proptest is not available offline).
//!
//! `check` runs a property over `n` seeded random cases and, on failure,
//! reports the failing seed so the case can be replayed deterministically:
//!
//! ```ignore
//! prop::check(200, |rng| {
//!     let k = rng.next_range(8) as usize + 1;
//!     let policy_k = policy.select(k_ctx(rng));
//!     prop::assert_prop(policy_k >= 1, "K must be at least 1")
//! });
//! ```

use super::rng::SplitMix64;

#[derive(Debug)]
pub struct PropFailure {
    pub seed: u64,
    pub case: usize,
    pub msg: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (replay seed {}): {}",
            self.case, self.seed, self.msg
        )
    }
}

/// Run `property` over `n` random cases. Panics with the failing seed on
/// the first counterexample. The base seed can be overridden with the
/// FLEXSPEC_PROP_SEED env var to replay a failure.
pub fn check<F>(n: usize, property: F)
where
    F: Fn(&mut SplitMix64) -> Result<(), String>,
{
    let base = std::env::var("FLEXSPEC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1EC_5EED_u64);
    for case in 0..n {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = SplitMix64::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("{}", PropFailure { seed, case, msg });
        }
    }
}

/// Readable assertion helper for properties.
pub fn assert_prop(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert |a - b| <= tol with a diagnostic message.
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        // interior mutability through a cell to count invocations
        let counter = std::cell::Cell::new(0usize);
        check(50, |rng| {
            counter.set(counter.get() + 1);
            let x = rng.next_f64();
            assert_prop((0.0..1.0).contains(&x), "f64 out of range")
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| {
            let x = rng.next_range(100);
            assert_prop(x < 50, format!("x={x} too large"))
        });
    }

    #[test]
    fn close_helper() {
        assert!(assert_close(1.0, 1.0005, 1e-3, "x").is_ok());
        assert!(assert_close(1.0, 1.1, 1e-3, "x").is_err());
    }
}
