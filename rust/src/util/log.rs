//! Minimal leveled logger with a global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= VERBOSITY.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag} {module}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $mod, &format!($($arg)*))
    };
}

/// Scope timer for coarse profiling (prints at Debug level on drop).
pub struct ScopeTimer {
    name: String,
    start: Instant,
}

impl ScopeTimer {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            start: Instant::now(),
        }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        log(
            Level::Debug,
            "timer",
            &format!("{}: {:.2} ms", self.name, self.elapsed_ms()),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn timer_measures() {
        let t = ScopeTimer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
