//! Minimal leveled logger with a global verbosity switch.
//!
//! Lines carry an elapsed-since-process-start timestamp and pass
//! through a per-module token bucket so a hot-path warn loop cannot
//! flood stderr (errors are exempt). `ScopeTimer` reads time through
//! [`crate::obs::Clock`], so a timer handed a simulator's virtual
//! clock measures virtual elapsed time instead of wall time.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::clock::{Clock, WallClock};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a `--log-level` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static VERBOSITY: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    VERBOSITY.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= VERBOSITY.load(Ordering::Relaxed)
}

/// Seconds since the first log call (process-lifetime origin).
fn uptime_s() -> f64 {
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

// ---------------------------------------------------------------------
// Per-module token-bucket rate limiting
// ---------------------------------------------------------------------

/// Burst capacity per module.
const RATE_BURST: f64 = 200.0;
/// Sustained refill, lines per second per module.
const RATE_PER_S: f64 = 50.0;

struct Bucket {
    tokens: f64,
    last_s: f64,
    suppressed: u64,
}

fn buckets() -> &'static Mutex<HashMap<String, Bucket>> {
    static BUCKETS: OnceLock<Mutex<HashMap<String, Bucket>>> = OnceLock::new();
    BUCKETS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Take one token for `module` at time `now_s`; returns
/// `Some(previously_suppressed)` if the line may print, `None` if it is
/// rate-limited. Errors bypass this entirely.
fn admit(module: &str, now_s: f64) -> Option<u64> {
    let mut map = buckets().lock().unwrap();
    let b = map.entry(module.to_string()).or_insert(Bucket {
        tokens: RATE_BURST,
        last_s: now_s,
        suppressed: 0,
    });
    b.tokens = (b.tokens + (now_s - b.last_s).max(0.0) * RATE_PER_S).min(RATE_BURST);
    b.last_s = now_s;
    if b.tokens >= 1.0 {
        b.tokens -= 1.0;
        Some(std::mem::take(&mut b.suppressed))
    } else {
        b.suppressed += 1;
        None
    }
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let now_s = uptime_s();
    let suppressed = if level == Level::Error {
        0
    } else {
        match admit(module, now_s) {
            Some(n) => n,
            None => return,
        }
    };
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    if suppressed > 0 {
        eprintln!("[{now_s:9.3} {tag} {module}] ({suppressed} lines rate-limited) {msg}");
    } else {
        eprintln!("[{now_s:9.3} {tag} {module}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $mod, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($mod:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $mod, &format!($($arg)*))
    };
}

/// Scope timer for coarse profiling (prints at Debug level on drop).
///
/// Defaults to a wall clock; `with_clock` routes it through any
/// [`Clock`], so sim-side code timing against a `VirtualClock` reports
/// virtual milliseconds.
pub struct ScopeTimer {
    name: String,
    clock: Arc<dyn Clock>,
    start_ms: f64,
}

impl ScopeTimer {
    pub fn new(name: impl Into<String>) -> Self {
        Self::with_clock(name, WallClock::shared())
    }

    pub fn with_clock(name: impl Into<String>, clock: Arc<dyn Clock>) -> Self {
        let start_ms = clock.now_ms();
        Self {
            name: name.into(),
            clock,
            start_ms,
        }
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.clock.now_ms() - self.start_ms
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        log(
            Level::Debug,
            "timer",
            &format!("{}: {:.2} ms", self.name, self.elapsed_ms()),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::clock::VirtualClock;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn level_parses() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn timer_measures() {
        let t = ScopeTimer::new("test");
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn timer_follows_virtual_clock() {
        let vc = VirtualClock::shared();
        vc.advance_to(100.0);
        let t = ScopeTimer::with_clock("virt", vc.clone());
        vc.advance_to(130.0);
        assert_eq!(t.elapsed_ms(), 30.0);
        // wall time passing does not move a virtual timer
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert_eq!(t.elapsed_ms(), 30.0);
    }

    #[test]
    fn rate_limiter_admits_then_suppresses() {
        let now = 1000.0;
        // a fresh module gets the full burst...
        for i in 0..(RATE_BURST as u64) {
            assert!(admit("test-rl-module", now).is_some(), "line {i}");
        }
        // ...then suppresses
        assert!(admit("test-rl-module", now).is_none());
        assert!(admit("test-rl-module", now).is_none());
        // refill after time passes, and the suppressed count is handed
        // back on the first admitted line
        let later = now + 1.0;
        assert_eq!(admit("test-rl-module", later), Some(2));
        // other modules are unaffected
        assert_eq!(admit("test-rl-other", now), Some(0));
    }
}
