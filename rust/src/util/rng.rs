//! Deterministic PRNG + distributions (no external crates).
//!
//! `SplitMix64` is bit-identical to `python/compile/corpus.py::SplitMix64`
//! — the cross-language contract that makes the rust workload generator
//! produce the *same distribution* the python training pipeline used.
//! Golden values are pinned in both test suites.

/// splitmix64 (Steele et al.); passes BigCrush for our purposes and is
/// trivially portable across languages.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53 bits of precision (matches python).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) via modulo (bias is irrelevant at our n;
    /// python side uses the identical reduction).
    #[inline]
    pub fn next_range(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with the given mu/sigma of the underlying normal.
    pub fn next_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next_normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Derive an independent stream (for per-session/per-component rngs).
    pub fn fork(&mut self, salt: u64) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_matches_python() {
        // python/tests/test_corpus.py::test_splitmix64_golden pins these.
        let mut r = SplitMix64::new(42);
        assert_eq!(r.next_u64(), 13679457532755275413);
        assert_eq!(r.next_u64(), 2949826092126892291);
        assert_eq!(r.next_u64(), 5139283748462763858);
        assert_eq!(r.next_u64(), 6349198060258255764);
    }

    #[test]
    fn f64_in_unit_interval_with_sane_mean() {
        let mut r = SplitMix64::new(7);
        let xs: Vec<f64> = (0..1000).map(|_| r.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((0.4..0.6).contains(&mean), "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = SplitMix64::new(4);
        let n = 20_000;
        let mean = (0..n).map(|_| r.next_exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn forks_are_decorrelated() {
        let mut r = SplitMix64::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn range_bounds() {
        let mut r = SplitMix64::new(6);
        for _ in 0..1000 {
            assert!(r.next_range(7) < 7);
            let x = r.range_f64(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }
}
