//! Hand-rolled substrates: nothing beyond `xla` + `anyhow` is available
//! offline, so JSON, CLI parsing, PRNG/distributions, stats, logging,
//! property testing and the bench harness are implemented here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
