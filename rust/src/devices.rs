//! Edge device + cloud server profiles (DESIGN.md substitution log).
//!
//! Table V only depends on the ratio between local drafting speed and the
//! network + cloud service rate; these profiles carry exactly the numbers
//! the paper reports for each device (draft ms/token) plus energy/thermal
//! coefficients for Table V / Fig. 6.

/// An edge device hosting the draft model (Table V).
#[derive(Debug, Clone)]
pub struct EdgeDevice {
    pub name: &'static str,
    pub processor: &'static str,
    /// alpha_edge of eq. (10): marginal draft latency per token, ms.
    pub draft_ms_per_token: f64,
    /// beta of eq. (10): fixed per-round edge overhead (scheduling,
    /// tokenizer, NPU dispatch), ms.
    pub round_overhead_ms: f64,
    /// Draft prefill throughput (prompt ingestion), ms per token.
    pub prefill_ms_per_token: f64,
    /// Active compute power while drafting, watts.
    pub compute_watts: f64,
    /// Radio transmit/receive power, watts (cellular active state).
    pub radio_active_watts: f64,
    /// Radio tail power after activity (the RRC tail the paper's Fig. 6
    /// blames for Cloud-Only energy), watts.
    pub radio_tail_watts: f64,
    /// Radio tail duration after each burst, ms.
    pub radio_tail_ms: f64,
    /// Idle platform power, watts.
    pub idle_watts: f64,
    /// Sustained thermal budget class (for the RQ5 discussion).
    pub thermal_class: &'static str,
}

pub const JETSON_ORIN: EdgeDevice = EdgeDevice {
    name: "Jetson AGX Orin",
    processor: "Ampere GPU",
    draft_ms_per_token: 8.5,
    round_overhead_ms: 2.0,
    prefill_ms_per_token: 1.2,
    compute_watts: 18.0,
    radio_active_watts: 1.1,
    radio_tail_watts: 0.6,
    radio_tail_ms: 120.0,
    idle_watts: 6.0,
    thermal_class: "Low-Med",
};

pub const IPHONE_15_PRO_MAX: EdgeDevice = EdgeDevice {
    name: "iPhone 15 Pro Max",
    processor: "A17 Pro (NPU)",
    draft_ms_per_token: 12.0,
    round_overhead_ms: 2.5,
    prefill_ms_per_token: 1.8,
    compute_watts: 4.5,
    radio_active_watts: 1.3,
    radio_tail_watts: 0.7,
    radio_tail_ms: 150.0,
    idle_watts: 0.9,
    thermal_class: "Low-Med",
};

pub const SNAPDRAGON_8G3: EdgeDevice = EdgeDevice {
    name: "Snapdragon 8 Gen 3",
    processor: "Hexagon NPU",
    draft_ms_per_token: 10.5,
    round_overhead_ms: 2.5,
    prefill_ms_per_token: 1.6,
    compute_watts: 5.0,
    radio_active_watts: 1.3,
    radio_tail_watts: 0.7,
    radio_tail_ms: 150.0,
    idle_watts: 1.0,
    thermal_class: "Low-Med",
};

pub const RASPBERRY_PI_5: EdgeDevice = EdgeDevice {
    name: "Raspberry Pi 5",
    processor: "Cortex-A76 (CPU)",
    draft_ms_per_token: 145.0,
    round_overhead_ms: 4.0,
    prefill_ms_per_token: 22.0,
    compute_watts: 7.5,
    radio_active_watts: 0.9,
    radio_tail_watts: 0.4,
    radio_tail_ms: 100.0,
    idle_watts: 2.7,
    thermal_class: "Med",
};

pub fn all_edge_devices() -> [&'static EdgeDevice; 4] {
    [&RASPBERRY_PI_5, &JETSON_ORIN, &IPHONE_15_PRO_MAX, &SNAPDRAGON_8G3]
}

pub fn edge_device(name: &str) -> Option<&'static EdgeDevice> {
    let n = name.to_ascii_lowercase();
    all_edge_devices()
        .into_iter()
        .find(|d| d.name.to_ascii_lowercase().contains(&n) || n.contains("jetson") && d.name.contains("Jetson"))
}

impl EdgeDevice {
    pub fn draft_throughput_tps(&self) -> f64 {
        1e3 / self.draft_ms_per_token
    }
}

/// A cloud serving tier hosting the target model.
///
/// Calibration: `t_base_ms` for the A800/70B pair is set so Cloud-Only
/// per-token latency lands near the paper's anchors (EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct CloudProfile {
    pub name: &'static str,
    /// T_base of eq. (9): fixed per-step verification cost, ms.
    pub t_base_ms: f64,
    /// delta_cloud of eq. (9): marginal per-verified-token cost, ms.
    pub delta_per_token_ms: f64,
    /// Prefill cost per prompt token, ms.
    pub prefill_ms_per_token: f64,
}

pub const A800_70B: CloudProfile = CloudProfile {
    name: "8xA800 / 70B-class",
    t_base_ms: 378.0,
    delta_per_token_ms: 4.0,
    prefill_ms_per_token: 0.9,
};

pub const H800_70B: CloudProfile = CloudProfile {
    name: "8xH800 / 70B-class",
    t_base_ms: 245.0,
    delta_per_token_ms: 2.6,
    prefill_ms_per_token: 0.6,
};

pub const V100_70B: CloudProfile = CloudProfile {
    name: "8xV100 / 70B-class",
    t_base_ms: 610.0,
    delta_per_token_ms: 6.5,
    prefill_ms_per_token: 1.5,
};

/// Llama-3 70B on H800-class serving (Table VI: baseline 395/550 ms).
pub const CLOUD_LLAMA3: CloudProfile = CloudProfile {
    name: "8xA800 / Llama-3-70B",
    t_base_ms: 341.0,
    delta_per_token_ms: 3.8,
    prefill_ms_per_token: 0.9,
};

/// Mixtral 8x7B: conditional compute → faster base step (Table VI:
/// baseline 320/485 ms).
pub const CLOUD_MIXTRAL: CloudProfile = CloudProfile {
    name: "8xA800 / Mixtral-8x7B",
    t_base_ms: 266.0,
    delta_per_token_ms: 2.2,
    prefill_ms_per_token: 0.5,
};

impl CloudProfile {
    /// eq. (9): verification latency for K tokens (+1 for the committed
    /// token row that rides along in the block).
    pub fn verify_ms(&self, k: usize) -> f64 {
        self.t_base_ms + k as f64 * self.delta_per_token_ms
    }

    pub fn prefill_ms(&self, prompt_len: usize) -> f64 {
        self.t_base_ms * 0.6 + prompt_len as f64 * self.prefill_ms_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_throughputs_match_paper() {
        assert!((RASPBERRY_PI_5.draft_throughput_tps() - 6.9).abs() < 0.1);
        assert!((JETSON_ORIN.draft_throughput_tps() - 117.6).abs() < 0.5);
        assert!((IPHONE_15_PRO_MAX.draft_throughput_tps() - 83.3).abs() < 0.5);
        assert!((SNAPDRAGON_8G3.draft_throughput_tps() - 95.2).abs() < 0.5);
    }

    #[test]
    fn verify_ms_is_affine() {
        let d0 = A800_70B.verify_ms(0);
        let d4 = A800_70B.verify_ms(4);
        let d8 = A800_70B.verify_ms(8);
        assert!((d8 - d4 - (d4 - d0)).abs() < 1e-9);
        assert!(d0 >= A800_70B.t_base_ms);
    }

    #[test]
    fn cloud_tiers_ordered() {
        assert!(H800_70B.t_base_ms < A800_70B.t_base_ms);
        assert!(A800_70B.t_base_ms < V100_70B.t_base_ms);
        assert!(CLOUD_MIXTRAL.t_base_ms < CLOUD_LLAMA3.t_base_ms);
    }

    #[test]
    fn device_lookup() {
        assert!(edge_device("jetson").is_some());
        assert!(edge_device("raspberry pi 5").is_some());
        assert!(edge_device("pdp-11").is_none());
    }
}
