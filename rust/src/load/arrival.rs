//! Arrival processes and heavy-tailed size distributions for the
//! fleet-scale load harness.
//!
//! Session arrivals follow a NON-homogeneous Poisson process: a base
//! rate shaped by a diurnal sinusoid and an optional flash-crowd burst
//! window, sampled by Lewis-Shedler thinning (draw candidate arrivals
//! at the peak rate `lambda_max`, keep each with probability
//! `lambda(t) / lambda_max`). Thinning keeps the stream deterministic
//! for a fixed seed regardless of the rate shape — the rejection draws
//! consume RNG state in a fixed order.
//!
//! Session sizes (token budgets, prompt lengths) are BOUNDED PARETO:
//! `x = xm * u^(-1/alpha)` clamped to a cap. Real chat populations are
//! heavy-tailed — most sessions are short, a fat tail runs for
//! hundreds of tokens — and the tail is exactly what stresses parked-
//! session bookkeeping and per-replica queues at scale.

use crate::util::rng::SplitMix64;

/// Shape of the arrival intensity `lambda(t)`.
#[derive(Debug, Clone, Copy)]
pub struct ArrivalShape {
    /// Base arrival rate, sessions per second of virtual time.
    pub base_per_s: f64,
    /// Diurnal modulation amplitude in [0, 1): rate swings between
    /// `base * (1 - amp)` and `base * (1 + amp)`.
    pub diurnal_amp: f64,
    /// Diurnal period, virtual ms (a compressed "day").
    pub diurnal_period_ms: f64,
    /// Flash-crowd multiplier applied inside the burst window
    /// (1.0 = no flash).
    pub flash_mult: f64,
    /// Burst window start, virtual ms.
    pub flash_start_ms: f64,
    /// Burst window duration, virtual ms.
    pub flash_dur_ms: f64,
}

impl ArrivalShape {
    /// A flat Poisson stream at `base_per_s`.
    pub fn steady(base_per_s: f64) -> ArrivalShape {
        ArrivalShape {
            base_per_s,
            diurnal_amp: 0.0,
            diurnal_period_ms: 86_400.0,
            flash_mult: 1.0,
            flash_start_ms: 0.0,
            flash_dur_ms: 0.0,
        }
    }

    /// Instantaneous intensity, sessions per second at virtual `t_ms`.
    pub fn lambda(&self, t_ms: f64) -> f64 {
        let wave = 1.0
            + self.diurnal_amp
                * (2.0 * std::f64::consts::PI * t_ms / self.diurnal_period_ms).sin();
        let flash = if self.flash_mult > 1.0
            && t_ms >= self.flash_start_ms
            && t_ms < self.flash_start_ms + self.flash_dur_ms
        {
            self.flash_mult
        } else {
            1.0
        };
        (self.base_per_s * wave * flash).max(0.0)
    }

    /// Peak intensity the thinning sampler proposes at.
    pub fn lambda_max(&self) -> f64 {
        self.base_per_s * (1.0 + self.diurnal_amp) * self.flash_mult.max(1.0)
    }
}

/// Deterministic non-homogeneous Poisson arrival stream (thinning).
#[derive(Debug, Clone)]
pub struct ArrivalProcess {
    shape: ArrivalShape,
    rng: SplitMix64,
    t_ms: f64,
}

impl ArrivalProcess {
    pub fn new(shape: ArrivalShape, rng: SplitMix64) -> ArrivalProcess {
        ArrivalProcess {
            shape,
            rng,
            t_ms: 0.0,
        }
    }

    /// Virtual timestamp (ms) of the next arrival.
    pub fn next_arrival_ms(&mut self) -> f64 {
        let lam_max = self.shape.lambda_max().max(1e-9);
        loop {
            // candidate gap at the peak rate, in ms
            self.t_ms += self.rng.next_exp(lam_max) * 1e3;
            let keep = self.shape.lambda(self.t_ms) / lam_max;
            if self.rng.chance(keep) {
                return self.t_ms;
            }
        }
    }
}

/// Bounded-Pareto sample: heavy-tailed in `[xm, cap]` with tail index
/// `alpha` (smaller alpha = fatter tail).
pub fn bounded_pareto(rng: &mut SplitMix64, xm: f64, alpha: f64, cap: f64) -> f64 {
    let u = rng.next_f64().max(1e-12);
    (xm * u.powf(-1.0 / alpha)).min(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_stream_matches_rate() {
        let mut p = ArrivalProcess::new(ArrivalShape::steady(100.0), SplitMix64::new(3));
        let mut last = 0.0;
        let n = 5000;
        for _ in 0..n {
            last = p.next_arrival_ms();
        }
        // 5000 arrivals at 100/s ≈ 50 s of virtual time (±20%)
        let secs = last / 1e3;
        assert!((40.0..60.0).contains(&secs), "{secs} s for {n} arrivals");
    }

    #[test]
    fn deterministic_per_seed() {
        let mk = || {
            let shape = ArrivalShape {
                diurnal_amp: 0.5,
                flash_mult: 10.0,
                flash_start_ms: 5_000.0,
                flash_dur_ms: 2_000.0,
                ..ArrivalShape::steady(50.0)
            };
            ArrivalProcess::new(shape, SplitMix64::new(17))
        };
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..1000 {
            assert_eq!(a.next_arrival_ms().to_bits(), b.next_arrival_ms().to_bits());
        }
    }

    #[test]
    fn flash_window_concentrates_arrivals() {
        let shape = ArrivalShape {
            flash_mult: 20.0,
            flash_start_ms: 10_000.0,
            flash_dur_ms: 5_000.0,
            ..ArrivalShape::steady(10.0)
        };
        let mut p = ArrivalProcess::new(shape, SplitMix64::new(42));
        let times: Vec<f64> = (0..2000).map(|_| p.next_arrival_ms()).collect();
        let in_burst = times
            .iter()
            .filter(|&&t| (10_000.0..15_000.0).contains(&t))
            .count();
        // the 5 s burst at 200/s should hold ~1000 of the first 2000
        assert!(
            in_burst > 600,
            "only {in_burst} of {} arrivals in the burst",
            times.len()
        );
        // arrivals are strictly increasing (no simultaneous sessions)
        assert!(times.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn diurnal_wave_modulates_rate() {
        let shape = ArrivalShape {
            diurnal_amp: 0.9,
            diurnal_period_ms: 20_000.0,
            ..ArrivalShape::steady(50.0)
        };
        // crest (sin = +1) vs trough (sin = -1)
        assert!(shape.lambda(5_000.0) > 90.0);
        assert!(shape.lambda(15_000.0) < 10.0);
        let mut p = ArrivalProcess::new(shape, SplitMix64::new(7));
        // count arrivals per half-period over a few cycles
        let mut crest = 0usize;
        let mut trough = 0usize;
        loop {
            let t = p.next_arrival_ms();
            if t > 100_000.0 {
                break;
            }
            if (t / 10_000.0) as u64 % 2 == 0 {
                crest += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            crest > 3 * trough,
            "crest {crest} vs trough {trough} arrivals"
        );
    }

    #[test]
    fn bounded_pareto_is_heavy_tailed_and_bounded() {
        let mut r = SplitMix64::new(3);
        let xs: Vec<f64> = (0..20_000)
            .map(|_| bounded_pareto(&mut r, 8.0, 1.1, 256.0))
            .collect();
        assert!(xs.iter().all(|&x| (8.0..=256.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let median = {
            let mut s = xs.clone();
            s.sort_by(f64::total_cmp);
            s[s.len() / 2]
        };
        // heavy tail: mean well above median, cap actually reached
        assert!(mean > 1.5 * median, "mean {mean} median {median}");
        assert!(xs.iter().any(|&x| x == 256.0), "cap never reached");
    }
}
