//! `flexspec::load` — fleet-scale workload generation on the virtual
//! clock (ROADMAP item 2; see `docs/LOADGEN.md`).
//!
//! The serving subsystem is proven correct session-by-session by the
//! `serve::*` tests; this module asks the SCALE question: what do the
//! tail latencies, queue depths, and handoff counts look like when
//! 10^4–10^6 concurrent sessions with heavy-tailed budgets arrive over
//! heterogeneous channels — including flash crowds and diurnal waves —
//! against a bounded fleet?
//!
//! Three layers:
//!
//! * [`arrival`] — non-homogeneous Poisson arrivals (diurnal sinusoid +
//!   flash-crowd bursts, sampled by thinning) and bounded-Pareto
//!   session sizes.
//! * [`population`] — heterogeneous channel mixes over the paper's
//!   three regimes, a compact per-session channel sampler (same
//!   dynamics as `StochasticChannel`, ~5 bytes of state per session),
//!   and the named [`Scenario`] presets (`steady` / `flash` /
//!   `diurnal` / `churn`).
//! * [`harness`] — the discrete-event simulator: per-replica admission
//!   windows and FIFO backlogs, eq. (9) batched verification costs,
//!   Busy deferrals on the edge's real [`busy_backoff_ms`]
//!   (`serve::edge`) schedule, cross-replica handoffs, and air-byte
//!   accounting — all reported through the serving stack's own
//!   [`ServingMetrics`](crate::metrics::ServingMetrics) vocabulary so
//!   `check_invariants` audits the simulation exactly like a live
//!   replica.
//!
//! Entry points: `Scenario::parse("flash").config(sessions, seed)` →
//! [`run`] → [`LoadReport`] (quantiles, peaks, digest). Reports are
//! deterministic per config — `LoadReport::digest` is the pin CI's
//! `BENCH_load.json` trajectory re-checks on every PR. The `loadgen`
//! CLI subcommand and `benches/load_scale.rs` wrap these.
//!
//! [`busy_backoff_ms`]: crate::serve::busy_backoff_ms

pub mod arrival;
pub mod harness;
pub mod population;

pub use arrival::{bounded_pareto, ArrivalProcess, ArrivalShape};
pub use harness::{run, run_with, AutoscaleReport, LoadReport, TRACE_SESSIONS};
pub use population::{sample_channel, ChannelMix, LoadConfig, Scenario};
