//! Event-driven fleet simulator on the virtual clock.
//!
//! One discrete-event loop drives 10^4–10^6 concurrent sessions against
//! a fleet of replicas without spawning a task per session: the heap
//! holds one in-flight event per live session plus one or two per
//! replica, so memory is O(sessions) with a ~100-byte constant and the
//! wall cost is O(events · log heap).
//!
//! The model reuses the serving stack's own building blocks rather than
//! re-deriving them: channel dynamics from [`sample_channel`] (the
//! `StochasticChannel` math over shared [`NetworkProfile`]s),
//! verification cost from the eq. (9) constants of
//! [`CloudProfile::verify_ms`](crate::devices::CloudProfile::verify_ms)
//! with K bucketing via [`bucket_k`], Busy deferral
//! pacing from the edge's exported [`busy_backoff_ms`] schedule, and
//! air-byte accounting from `protocol`. Results flow through the same
//! [`ServingMetrics`] the live verifier keeps, so its conservation
//! audit (`check_invariants`) applies verbatim to a million simulated
//! sessions.
//!
//! Replica model: each session is pinned to a replica (its KV state
//! lives there). Drafts land in a per-replica FIFO backlog; an
//! admission window closes `window_ms` after the first draft arrives
//! (or after the previous batch retires, under saturation) and takes up
//! to `max_batch` drafts into one verification batch. Under overload
//! the backlog — and the queue-wait quantiles — grow without bound
//! unless `admission_queue` bounds it, in which case excess drafts get
//! the wire's `Busy` deferral and back off on the edge's schedule.
//!
//! Determinism contract: a run is a pure function of [`LoadConfig`]
//! (including the seed). Every random draw flows from `SplitMix64`
//! streams forked per subsystem/session in a fixed order, and the event
//! heap breaks time ties by sequence number, so reports — including
//! [`LoadReport::digest`] — are byte-identical across runs and across
//! machines.
//!
//! Autoscale twin: with [`LoadConfig::autoscale`] set, the SAME
//! [`AutoscalePolicy`] that drives the live fleet controller ticks on
//! the virtual clock ([`Ev::AutoscaleTick`]): scale-ups grow the
//! replica table, scale-downs drain a replica (its sessions evacuate
//! at their next head round, exactly where the live verifier exports),
//! rebalance directives move up to `sessions` pinned sessions per tick
//! under the per-session redirect budget, and Busy deferrals quote the
//! queue-depth-adaptive [`adaptive_retry_after_ms`] instead of the
//! static window. The policy's action log rides the report
//! ([`AutoscaleReport::log_digest`]), extending the byte-identity pin
//! to the control plane. With `autoscale == None` every draw, event,
//! and counter is exactly the pre-autoscale harness.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::autoscale::{
    adaptive_retry_after_ms, AutoscaleAction, AutoscalePolicy, ReplicaSnapshot, CONTROL_SESSION,
};
use crate::channel::{ChannelState, NetworkProfile};
use crate::device::ComputeTier;
use crate::devices::{A800_70B, JETSON_ORIN};
use crate::energy::EnergyBudget;
use crate::metrics::ServingMetrics;
use crate::obs::{LogHistogram, SpanKind, Trace};
use crate::protocol::{bits_per_token, prompt_air_bytes, WireFormat, O_HEADER_BYTES};
use crate::serve::{bucket_k, busy_backoff_ms, BatchMode, MAX_BUSY_RETRIES};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

use super::arrival::{bounded_pareto, ArrivalProcess};
use super::population::{sample_channel, LoadConfig};

/// Sessions whose spans are recorded when a [`Trace`] is attached —
/// tracing every session at fleet scale would swamp the journal.
pub const TRACE_SESSIONS: u32 = 64;

/// Safety valve against scheduling bugs: no workload needs more than
/// this many events per admitted session (a full Busy-retry storm on
/// every round stays well under it).
const MAX_EVENTS_PER_SESSION: u64 = 4000;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Ev {
    /// Admit the next session from the arrival process.
    Admit,
    /// A draft (uplink done) reaches its replica's admission queue.
    DraftArrive { sid: u32 },
    /// A replica's admission window closes: form a batch.
    WindowClose { rep: u16 },
    /// A replica's in-flight batch retires.
    ReplicaFree { rep: u16 },
    /// A verdict (downlink done) reaches the edge.
    Verdict { sid: u32, tau: u8, eos: bool },
    /// Busy-deferral backoff expired: resend the draft.
    Retry { sid: u32 },
    /// One control-loop period elapsed: feed the autoscale policy a
    /// snapshot of the replica table and apply its actions.
    AutoscaleTick,
}

#[derive(Debug)]
struct Sched {
    at_ms: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for Sched {
    fn eq(&self, other: &Sched) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Sched {}
impl PartialOrd for Sched {
    fn partial_cmp(&self, other: &Sched) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Sched {
    fn cmp(&self, other: &Sched) -> std::cmp::Ordering {
        // ascending time; sequence number breaks ties deterministically
        self.at_ms
            .total_cmp(&other.at_ms)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Compact per-session state (~96 bytes): at 10^6 sessions the
/// population fits in well under 100 MB.
struct Sess {
    rng: SplitMix64,
    arrived_ms: f64,
    /// When drafting of the in-flight round started (edge-side).
    send_ms: f64,
    /// When the in-flight draft entered the replica backlog.
    enqueue_ms: f64,
    first_token_ms: f64,
    log_shadow: f32,
    accept: f32,
    budget: u16,
    committed: u16,
    prompt_len: u16,
    rounds: u16,
    replica: u16,
    class: u8,
    /// Compute-tier code ([`ComputeTier::code`]); drawn from the device
    /// mix on hetero runs, pinned to Strong (whose representative is the
    /// fleet's homogeneous JETSON_ORIN) otherwise.
    tier: u8,
    busy_attempts: u8,
    /// Rebalance redirects consumed inside the current redirect window
    /// (autoscale only; the per-session budget gate).
    redirects_used: u8,
    /// Which redirect window `redirects_used` counts against.
    redirect_epoch: u32,
    fading: bool,
    done: bool,
}

#[derive(Default)]
struct Replica {
    backlog: VecDeque<u32>,
    busy: bool,
    close_armed: bool,
    /// Live sessions pinned here (autoscale sizing + drain tracking).
    pinned: usize,
    /// Draining: no placement, sessions evacuate at their head rounds.
    draining: bool,
    /// Fully drained and removed from service (id stays stable).
    retired: bool,
    /// Armed rebalance directive: up to `.1` sessions move to `.0` at
    /// their next head round. Re-armed (or cleared) every tick.
    rebalance_out: Option<(u16, usize)>,
}

/// What the autoscale twin did during one run (present iff
/// [`LoadConfig::autoscale`] was set).
#[derive(Debug, Clone)]
pub struct AutoscaleReport {
    /// Replicas spawned by `ScaleUp` actions.
    pub replicas_added: usize,
    /// Replicas fully drained and retired after `ScaleDown`.
    pub replicas_retired: usize,
    /// Sessions moved by the autoscaler (rebalance + drain evacuation).
    pub redirects: usize,
    /// Non-retired replicas when the run drained.
    pub final_replicas: usize,
    /// Total actions in the policy log.
    pub actions: usize,
    /// Most rebalance redirects any single session absorbed within one
    /// redirect window — the budget pin (`<= redirect_budget`).
    pub peak_session_redirects: u8,
    /// [`AutoscalePolicy::log_digest`] — byte-identity pin for the
    /// action log.
    pub log_digest: u64,
    /// Human-readable `tick action` lines for `--action-log` export.
    /// Identity is pinned by `log_digest`; these are not re-digested.
    pub log_lines: Vec<String>,
}

/// Everything one load run reports.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub scenario: &'static str,
    pub sessions: usize,
    pub replicas: usize,
    pub seed: u64,
    /// The serving stack's own counter vocabulary; passes
    /// `check_invariants(0, 0)` after a full drain.
    pub metrics: ServingMetrics,
    /// Time-to-first-token per session (virtual ms).
    pub ttft_ms: LogHistogram,
    /// End-to-end ms per committed token per completed session.
    pub ms_per_token: LogHistogram,
    /// Maximum concurrently-live sessions observed.
    pub peak_live: usize,
    /// Deepest per-replica backlog observed.
    pub peak_backlog: usize,
    /// Cross-replica session handoffs performed.
    pub handoffs: usize,
    /// Discrete events processed.
    pub events: u64,
    /// Virtual timestamp of the last event (run length).
    pub virtual_ms: f64,
    /// Pure transmission airtime (up + down, ex propagation), ms.
    pub air_ms: f64,
    /// Smallest `retry_after_ms` quoted on a Busy deferral (0 when
    /// none were sent). Static mode quotes one window; autoscale mode
    /// quotes the queue-depth-adaptive value, so the min/max spread
    /// shows how far the backlog pushed the hint.
    pub retry_after_min_ms: u32,
    /// Largest `retry_after_ms` quoted on a Busy deferral.
    pub retry_after_max_ms: u32,
    /// Autoscale-twin summary (`None` without [`LoadConfig::autoscale`]).
    pub autoscale: Option<AutoscaleReport>,
    /// Time-to-first-token per compute tier (weak/mid/strong), populated
    /// only on hetero runs ([`LoadConfig::device_mix`]).
    pub ttft_by_tier: [LogHistogram; 3],
    /// Draft-compute energy spent per tier (J), priced by
    /// [`EnergyBudget::draft_cost_j`] at the tier representative's
    /// speed/power over the tier-capped tree node count. Hetero runs only.
    pub energy_j_by_tier: [f64; 3],
    /// Tokens committed by sessions of each tier. Hetero runs only.
    pub tokens_by_tier: [usize; 3],
}

impl LoadReport {
    /// Airtime spent per committed token, ms — the edge-energy proxy
    /// the paper's eq. (8) accounting cares about.
    pub fn air_ms_per_token(&self) -> f64 {
        self.air_ms / self.metrics.tokens_committed.max(1) as f64
    }

    /// Whether this run carried a heterogeneous device population
    /// (some session was admitted with a drawn compute tier).
    pub fn is_hetero(&self) -> bool {
        self.metrics.sessions_by_device_tier.iter().sum::<usize>() > 0
    }

    /// Accepted draft tokens per stacked `[B, K]` dispatch — the
    /// efficiency ratio the hetero bench cell gates (tree speculation
    /// must not lose to linear chains on the same dispatch budget).
    pub fn accepted_per_dispatch(&self) -> f64 {
        self.metrics.accepted as f64 / self.metrics.stacked_dispatches.max(1) as f64
    }

    /// Order-sensitive FNV-1a fold over every counter and the latency
    /// quantiles. Two runs of the same config are byte-identical iff
    /// their digests match — the determinism pin CI re-checks each PR.
    pub fn digest(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        };
        let m = &self.metrics;
        for c in [
            m.sessions_opened,
            m.sessions_completed,
            m.sessions_aborted,
            m.sessions_redirected,
            m.sessions_imported,
            m.drafts_received,
            m.drafts_busy,
            m.rounds,
            m.batches,
            m.tokens_committed,
            m.drafted,
            m.accepted,
            m.bytes_up,
            m.bytes_down,
            self.peak_live,
            self.peak_backlog,
            self.handoffs,
        ] {
            mix(c as u64);
        }
        mix(self.events);
        mix(self.virtual_ms.to_bits());
        mix(self.air_ms.to_bits());
        mix(self.retry_after_min_ms as u64);
        mix(self.retry_after_max_ms as u64);
        if let Some(a) = &self.autoscale {
            mix(a.replicas_added as u64);
            mix(a.replicas_retired as u64);
            mix(a.redirects as u64);
            mix(a.final_replicas as u64);
            mix(a.actions as u64);
            mix(a.peak_session_redirects as u64);
            mix(a.log_digest);
        }
        for q in [
            self.ttft_ms.quantile(0.5),
            self.ttft_ms.quantile(0.99),
            self.ttft_ms.quantile(0.999),
            self.ms_per_token.quantile(0.5),
            self.ms_per_token.quantile(0.99),
            m.latency.queue_ms.quantile(0.99),
            m.latency.round_ms.quantile(0.99),
        ] {
            mix(q.to_bits());
        }
        // hetero-only extension: homogeneous runs skip this block
        // entirely, so their digests are byte-identical to the
        // pre-device-layer harness
        if self.is_hetero() {
            mix(m.verify_rows as u64);
            mix(m.tree_rounds as u64);
            for i in 0..3 {
                mix(m.sessions_by_device_tier[i] as u64);
                mix(self.tokens_by_tier[i] as u64);
                mix(self.energy_j_by_tier[i].to_bits());
                mix(self.ttft_by_tier[i].quantile(0.5).to_bits());
                mix(self.ttft_by_tier[i].quantile(0.99).to_bits());
            }
        }
        h
    }

    pub fn to_json(&self) -> Json {
        // empty histograms quantile to NaN; encode those as null
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let q = |hist: &LogHistogram| {
            Json::obj(vec![
                ("p50", num(hist.quantile(0.5))),
                ("p90", num(hist.quantile(0.9))),
                ("p99", num(hist.quantile(0.99))),
                ("p999", num(hist.quantile(0.999))),
                ("mean", num(hist.mean())),
                ("count", Json::Num(hist.count() as f64)),
            ])
        };
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.into())),
            ("sessions", Json::Num(self.sessions as f64)),
            ("replicas", Json::Num(self.replicas as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("peak_live", Json::Num(self.peak_live as f64)),
            ("peak_backlog", Json::Num(self.peak_backlog as f64)),
            ("handoffs", Json::Num(self.handoffs as f64)),
            ("events", Json::Num(self.events as f64)),
            ("virtual_ms", Json::Num(self.virtual_ms)),
            ("air_ms_per_token", Json::Num(self.air_ms_per_token())),
            ("retry_after_min_ms", Json::Num(self.retry_after_min_ms as f64)),
            ("retry_after_max_ms", Json::Num(self.retry_after_max_ms as f64)),
            (
                "autoscale",
                match &self.autoscale {
                    None => Json::Null,
                    Some(a) => Json::obj(vec![
                        ("replicas_added", Json::Num(a.replicas_added as f64)),
                        ("replicas_retired", Json::Num(a.replicas_retired as f64)),
                        ("redirects", Json::Num(a.redirects as f64)),
                        ("final_replicas", Json::Num(a.final_replicas as f64)),
                        ("actions", Json::Num(a.actions as f64)),
                        (
                            "peak_session_redirects",
                            Json::Num(a.peak_session_redirects as f64),
                        ),
                        ("log_digest", Json::Str(format!("{:016x}", a.log_digest))),
                    ]),
                },
            ),
            ("ttft_ms", q(&self.ttft_ms)),
            ("ms_per_token", q(&self.ms_per_token)),
            (
                "tiers",
                if !self.is_hetero() {
                    Json::Null
                } else {
                    Json::Arr(
                        (0..3)
                            .map(|i| {
                                let tokens = self.tokens_by_tier[i];
                                Json::obj(vec![
                                    (
                                        "tier",
                                        Json::Str(["weak", "mid", "strong"][i].into()),
                                    ),
                                    (
                                        "sessions",
                                        Json::Num(
                                            self.metrics.sessions_by_device_tier[i] as f64,
                                        ),
                                    ),
                                    ("tokens", Json::Num(tokens as f64)),
                                    ("ttft_ms", q(&self.ttft_by_tier[i])),
                                    (
                                        "draft_energy_j",
                                        Json::Num(self.energy_j_by_tier[i]),
                                    ),
                                    (
                                        "energy_mj_per_token",
                                        Json::Num(
                                            self.energy_j_by_tier[i] * 1e3
                                                / tokens.max(1) as f64,
                                        ),
                                    ),
                                ])
                            })
                            .collect(),
                    )
                },
            ),
            (
                "accepted_per_dispatch",
                Json::Num(self.accepted_per_dispatch()),
            ),
            ("digest", Json::Str(format!("{:016x}", self.digest()))),
            ("metrics", self.metrics.to_json()),
        ])
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "load/{} — {} sessions on {} replicas (seed {})\n\
             \x20 peak            {} live sessions, backlog depth {}, {} handoffs\n\
             \x20 run             {} events over {:.1} s virtual\n\
             \x20 ttft            p50 {:.0} ms, p99 {:.0} ms, p999 {:.0} ms\n\
             \x20 ms/token        p50 {:.1}, p99 {:.1}\n\
             \x20 airtime         {:.2} ms per committed token\n\
             \x20 digest          {:016x}",
            self.scenario,
            self.sessions,
            self.replicas,
            self.seed,
            self.peak_live,
            self.peak_backlog,
            self.handoffs,
            self.events,
            self.virtual_ms / 1e3,
            self.ttft_ms.quantile(0.5),
            self.ttft_ms.quantile(0.99),
            self.ttft_ms.quantile(0.999),
            self.ms_per_token.quantile(0.5),
            self.ms_per_token.quantile(0.99),
            self.air_ms_per_token(),
            self.digest(),
        );
        if self.retry_after_max_ms > 0 {
            s.push_str(&format!(
                "\n\x20 busy hints      retry_after {}–{} ms",
                self.retry_after_min_ms, self.retry_after_max_ms
            ));
        }
        if self.is_hetero() {
            s.push_str(&format!(
                "\n\x20 tree            {:.2} accepted/dispatch, {} tree rounds, {} rows",
                self.accepted_per_dispatch(),
                self.metrics.tree_rounds,
                self.metrics.verify_rows,
            ));
            for (i, name) in ["weak", "mid", "strong"].iter().enumerate() {
                s.push_str(&format!(
                    "\n\x20 tier {:<6}     {} sessions, ttft p50 {:.0} ms, \
                     {:.1} J drafted ({:.2} mJ/token)",
                    name,
                    self.metrics.sessions_by_device_tier[i],
                    self.ttft_by_tier[i].quantile(0.5),
                    self.energy_j_by_tier[i],
                    self.energy_j_by_tier[i] * 1e3 / self.tokens_by_tier[i].max(1) as f64,
                ));
            }
        }
        if let Some(a) = &self.autoscale {
            s.push_str(&format!(
                "\n\x20 autoscale       +{} replicas, {} retired, {} redirects, \
                 {} final, {} actions, log {:016x}",
                a.replicas_added,
                a.replicas_retired,
                a.redirects,
                a.final_replicas,
                a.actions,
                a.log_digest,
            ));
        }
        s.push('\n');
        s.push_str(&self.metrics.render("  serving counters"));
        s
    }
}

fn push(heap: &mut BinaryHeap<Reverse<Sched>>, seq: &mut u64, at_ms: f64, ev: Ev) {
    heap.push(Reverse(Sched {
        at_ms,
        seq: *seq,
        ev,
    }));
    *seq += 1;
}

fn chan(profiles: &[NetworkProfile; 3], s: &mut Sess) -> ChannelState {
    sample_channel(
        &profiles[s.class as usize],
        &mut s.log_shadow,
        &mut s.fading,
        &mut s.rng,
    )
}

/// Least-loaded active replica other than `not` — where a draining
/// replica's sessions evacuate to (mirrors `FleetRegistry::pick_peer`
/// with the sim's always-fresh snapshots; ties break by id).
fn least_loaded_active(replicas: &[Replica], not: usize) -> Option<u16> {
    replicas
        .iter()
        .enumerate()
        .filter(|&(i, r)| i != not && !r.draining && !r.retired)
        .min_by_key(|&(i, r)| (r.pinned + r.backlog.len(), i))
        .map(|(i, _)| i as u16)
}

/// Next active replica after `from` in cyclic id order — the scenario
/// `redirect_p` hop under autoscale, which must skip drained/retired
/// ids the static `(r + 1) % replicas` hop could land on.
fn next_active(replicas: &[Replica], from: u16) -> u16 {
    let n = replicas.len();
    for step in 1..=n {
        let i = (from as usize + step) % n;
        if !replicas[i].draining && !replicas[i].retired {
            return i as u16;
        }
    }
    from
}

/// Run a workload to completion. See [`run_with`] for tracing.
pub fn run(cfg: &LoadConfig) -> LoadReport {
    run_with(cfg, None)
}

/// Run a workload, recording spans for the first [`TRACE_SESSIONS`]
/// sessions into `trace` (whose clock is advanced to virtual time).
pub fn run_with(cfg: &LoadConfig, trace: Option<&Trace>) -> LoadReport {
    assert!(cfg.sessions > 0 && cfg.replicas > 0 && cfg.max_batch > 0);
    assert!(cfg.replicas <= u16::MAX as usize && cfg.sessions <= u32::MAX as usize);
    let mut master = SplitMix64::new(cfg.seed);
    let mut arrivals = ArrivalProcess::new(cfg.shape, master.fork(0xA5));
    let profiles: [NetworkProfile; 3] = {
        let kinds = crate::channel::NetworkKind::all();
        [
            NetworkProfile::new(kinds[0]),
            NetworkProfile::new(kinds[1]),
            NetworkProfile::new(kinds[2]),
        ]
    };
    let draft_ms =
        JETSON_ORIN.round_overhead_ms + cfg.fixed_k as f64 * JETSON_ORIN.draft_ms_per_token;
    // continuous batching never waits for stragglers: the close fires
    // as soon as the event loop drains the instant's arrivals (the
    // rolling-slot analogue of the verifier's zero-delay deadline)
    let window_arm_ms = match cfg.batch_mode {
        BatchMode::Continuous => 0.0,
        BatchMode::Windowed => cfg.window_ms,
    };
    let draft_bytes = O_HEADER_BYTES
        + ((cfg.fixed_k as f64 * bits_per_token(WireFormat::Compact)) / 8.0).ceil() as usize;
    let verdict_bytes = O_HEADER_BYTES + 12;
    let per_req_verify_ms = A800_70B.delta_per_token_ms * (bucket_k(cfg.fixed_k) + 1) as f64;

    // Heterogeneous-population twin (wire v8): sessions draw a compute
    // tier from the mix and draft bucket-aligned comb trees capped by
    // their tier's plan. The per-tier tables below price drafting at
    // the tier REPRESENTATIVE's speed/power — for the homogeneous fleet
    // (tier pinned to Strong, branching 1) they reproduce the scalar
    // `draft_ms`/`draft_bytes` bit-for-bit, because Strong's
    // representative IS the fleet's JETSON_ORIN.
    let hetero = cfg.device_mix.is_some();
    let branching = if hetero {
        cfg.branching.clamp(1, crate::device::MAX_BRANCHING)
    } else {
        1
    };
    // chain positions whose path length shares the chain's bucket class
    // — the only places the comb hangs alternates (backend::propose_tree)
    let aligned = (1..=cfg.fixed_k)
        .filter(|&p| bucket_k(p) == bucket_k(cfg.fixed_k))
        .count();
    let mut tier_branch = [1usize; 3];
    let mut tier_rows = [1usize; 3];
    let mut tier_draft_ms = [draft_ms; 3];
    let mut tier_draft_bytes = [draft_bytes; 3];
    let mut tier_draft_j = [0.0f64; 3];
    for t in [ComputeTier::Weak, ComputeTier::Mid, ComputeTier::Strong] {
        let i = t.code() as usize;
        let b = t.plan_caps().branching.min(branching).max(1);
        let rep = t.representative();
        let nodes = cfg.fixed_k + aligned * (b - 1);
        tier_branch[i] = b;
        tier_rows[i] = 1 + aligned * (b - 1);
        tier_draft_ms[i] = rep.round_overhead_ms + nodes as f64 * rep.draft_ms_per_token;
        // tree drafts add the zero-length-spec marker (2 bytes) plus one
        // parent byte per node to the linear payload (protocol::frame)
        tier_draft_bytes[i] = O_HEADER_BYTES
            + ((nodes as f64 * bits_per_token(WireFormat::Compact)) / 8.0).ceil() as usize
            + if b > 1 { 2 + nodes } else { 0 };
        tier_draft_j[i] = EnergyBudget::draft_cost_j(rep, nodes);
    }

    let mut sessions: Vec<Sess> = Vec::with_capacity(cfg.sessions);
    let mut replicas: Vec<Replica> = (0..cfg.replicas).map(|_| Replica::default()).collect();
    let mut metrics = ServingMetrics::default();
    let mut ttft_ms = LogHistogram::default();
    let mut ms_per_token = LogHistogram::default();
    let mut ttft_by_tier: [LogHistogram; 3] = std::array::from_fn(|_| LogHistogram::default());
    let mut energy_j_by_tier = [0.0f64; 3];
    let mut tokens_by_tier = [0usize; 3];
    let mut heap: BinaryHeap<Reverse<Sched>> = BinaryHeap::new();
    let mut seq = 0u64;
    let (mut live, mut peak_live, mut peak_backlog, mut handoffs) = (0usize, 0usize, 0usize, 0usize);
    let mut air_ms = 0.0f64;
    let mut events = 0u64;
    let mut now = 0.0f64;
    let max_events = cfg.sessions as u64 * MAX_EVENTS_PER_SESSION + 10_000;
    // autoscale-twin state (inert when cfg.autoscale is None)
    let mut autoscaler = cfg.autoscale.as_ref().map(|ac| AutoscalePolicy::new(ac.clone()));
    let mut tick_no = 0u64;
    let (mut replicas_added, mut replicas_retired, mut auto_redirects) = (0usize, 0usize, 0usize);
    let mut peak_session_redirects = 0u8;
    let (mut retry_after_min, mut retry_after_max) = (u32::MAX, 0u32);

    let traced = |sid: u32| sid < TRACE_SESSIONS;
    let span = |trace: Option<&Trace>, t: f64, sid: u32, round: u32, kind: SpanKind, dur: f64, a: u32, b: u32| {
        if let Some(tr) = trace {
            if traced(sid) {
                tr.clock().advance_to(t);
                tr.record(sid, round, kind, dur, a, b);
            }
        }
    };

    push(&mut heap, &mut seq, arrivals.next_arrival_ms(), Ev::Admit);
    if let Some(ac) = &cfg.autoscale {
        assert!(ac.max_replicas <= u16::MAX as usize, "autoscale ceiling exceeds u16 ids");
        push(&mut heap, &mut seq, ac.tick_ms, Ev::AutoscaleTick);
    }

    while let Some(Reverse(Sched { at_ms: t, ev, .. })) = heap.pop() {
        now = t;
        events += 1;
        assert!(events <= max_events, "load harness event storm: {ev:?} at {t}");
        match ev {
            Ev::Admit => {
                let sid = sessions.len() as u32;
                let mut srng = master.fork(0x5E55 + sid as u64);
                let class = cfg.mix.pick(&mut srng);
                let budget = bounded_pareto(&mut srng, cfg.budget_xm, cfg.budget_alpha, cfg.budget_cap)
                    .round()
                    .max(1.0) as u16;
                let prompt_len =
                    bounded_pareto(&mut srng, cfg.prompt_xm, cfg.prompt_alpha, cfg.prompt_cap)
                        .round() as u16;
                let accept = cfg.draw_accept(&mut srng) as f32;
                // the tier draw is skipped entirely on homogeneous
                // runs, so every pre-device-layer per-session stream
                // stays byte-identical
                let tier = match &cfg.device_mix {
                    Some(mix) => mix.pick(&mut srng).code(),
                    None => ComputeTier::Strong.code(),
                };
                // same draw position either way; under autoscale it
                // lands among the currently-ACTIVE replicas only
                let replica = if cfg.autoscale.is_some() {
                    let eligible: Vec<u16> = replicas
                        .iter()
                        .enumerate()
                        .filter(|&(_, r)| !r.draining && !r.retired)
                        .map(|(i, _)| i as u16)
                        .collect();
                    debug_assert!(!eligible.is_empty(), "no active replica to admit into");
                    eligible[srng.next_range(eligible.len() as u64) as usize]
                } else {
                    srng.next_range(cfg.replicas as u64) as u16
                };
                replicas[replica as usize].pinned += 1;
                let mut s = Sess {
                    rng: srng,
                    arrived_ms: t,
                    send_ms: t,
                    enqueue_ms: t,
                    first_token_ms: f64::NAN,
                    log_shadow: 0.0,
                    accept,
                    budget,
                    committed: 0,
                    prompt_len,
                    rounds: 0,
                    replica,
                    class,
                    tier,
                    busy_attempts: 0,
                    redirects_used: 0,
                    redirect_epoch: 0,
                    fading: false,
                    done: false,
                };
                metrics.sessions_opened += 1;
                if hetero {
                    metrics.sessions_by_device_tier[tier as usize] += 1;
                    energy_j_by_tier[tier as usize] += tier_draft_j[tier as usize];
                }
                live += 1;
                peak_live = peak_live.max(live);
                // first uplink carries the prompt alongside round 0's draft
                let t_draft = tier_draft_ms[tier as usize];
                let ch = chan(&profiles, &mut s);
                let bytes = prompt_air_bytes(prompt_len as usize) + tier_draft_bytes[tier as usize];
                let up = ch.up_ms(bytes);
                metrics.bytes_up += bytes;
                air_ms += up;
                span(trace, t, sid, 0, SpanKind::Draft, t_draft, cfg.fixed_k as u32, 0);
                span(trace, t, sid, 0, SpanKind::Uplink, up + ch.prop_ms, bytes as u32, 0);
                push(&mut heap, &mut seq, t + t_draft + up + ch.prop_ms, Ev::DraftArrive { sid });
                sessions.push(s);
                if sessions.len() < cfg.sessions {
                    push(&mut heap, &mut seq, arrivals.next_arrival_ms(), Ev::Admit);
                }
            }
            Ev::DraftArrive { sid } => {
                let s = &mut sessions[sid as usize];
                debug_assert!(!s.done);
                metrics.drafts_received += 1;
                // autoscale seam: a draining source evacuates the
                // session at its head round, an armed rebalance
                // directive moves it under the per-session budget —
                // both answer the draft with the wire's Redirect
                // (swallowed, redrafted at the target), exactly where
                // the live verifier exports
                if let Some(ac) = &cfg.autoscale {
                    let from = s.replica as usize;
                    let target: Option<u16> = if replicas[from].draining {
                        least_loaded_active(&replicas, from)
                    } else if let Some((to, left)) = replicas[from].rebalance_out {
                        let dst = &replicas[to as usize];
                        if left == 0 || dst.draining || dst.retired {
                            None
                        } else {
                            let epoch =
                                (tick_no / ac.redirect_window_ticks.max(1) as u64) as u32;
                            if s.redirect_epoch != epoch {
                                s.redirect_epoch = epoch;
                                s.redirects_used = 0;
                            }
                            if s.redirects_used < ac.redirect_budget {
                                s.redirects_used += 1;
                                peak_session_redirects =
                                    peak_session_redirects.max(s.redirects_used);
                                replicas[from].rebalance_out = Some((to, left - 1));
                                Some(to)
                            } else {
                                None
                            }
                        }
                    } else {
                        None
                    };
                    if let Some(to) = target {
                        metrics.drafts_swallowed += 1;
                        metrics.sessions_redirected += 1;
                        metrics.sessions_imported += 1;
                        handoffs += 1;
                        auto_redirects += 1;
                        replicas[from].pinned -= 1;
                        replicas[to as usize].pinned += 1;
                        s.replica = to;
                        span(
                            trace,
                            t,
                            sid,
                            s.rounds as u32,
                            SpanKind::Redirect,
                            cfg.handoff_ms,
                            to as u32,
                            1,
                        );
                        // the edge follows the redirect and redrafts
                        // at the target after the handoff
                        let bytes = tier_draft_bytes[s.tier as usize];
                        if hetero {
                            energy_j_by_tier[s.tier as usize] += tier_draft_j[s.tier as usize];
                        }
                        let ch = chan(&profiles, s);
                        let up = ch.up_ms(bytes);
                        metrics.bytes_up += bytes;
                        air_ms += up;
                        s.send_ms = t + cfg.handoff_ms;
                        push(
                            &mut heap,
                            &mut seq,
                            t + cfg.handoff_ms + tier_draft_ms[s.tier as usize] + up + ch.prop_ms,
                            Ev::DraftArrive { sid },
                        );
                        continue;
                    }
                }
                let r = &mut replicas[s.replica as usize];
                if cfg.admission_queue > 0 && r.backlog.len() >= cfg.admission_queue {
                    metrics.drafts_busy += 1;
                    s.busy_attempts += 1;
                    if s.busy_attempts as usize > MAX_BUSY_RETRIES {
                        // the edge gives up after the retry budget —
                        // same outcome as run_edge_session erroring out
                        s.done = true;
                        live -= 1;
                        metrics.sessions_aborted += 1;
                        r.pinned -= 1;
                    } else {
                        // the verifier suggests a retry horizon — one
                        // window statically, queue-depth-adaptive under
                        // autoscale (the live verifier's same formula)
                        // — and the edge escalates on ITS schedule
                        let base = if cfg.autoscale.is_some() {
                            adaptive_retry_after_ms(
                                cfg.window_ms,
                                r.backlog.len(),
                                cfg.max_batch,
                            )
                        } else {
                            cfg.window_ms.ceil() as u32
                        };
                        retry_after_min = retry_after_min.min(base);
                        retry_after_max = retry_after_max.max(base);
                        let delay =
                            busy_backoff_ms(base, s.busy_attempts as usize - 1) as f64;
                        push(&mut heap, &mut seq, t + delay, Ev::Retry { sid });
                    }
                } else {
                    s.busy_attempts = 0;
                    s.enqueue_ms = t;
                    r.backlog.push_back(sid);
                    peak_backlog = peak_backlog.max(r.backlog.len());
                    if !r.busy && !r.close_armed {
                        r.close_armed = true;
                        let rep = s.replica;
                        push(&mut heap, &mut seq, t + window_arm_ms, Ev::WindowClose { rep });
                    }
                }
            }
            Ev::Retry { sid } => {
                let s = &mut sessions[sid as usize];
                if !s.done {
                    // resend of the already-drafted block: airtime only,
                    // no fresh draft compute
                    let bytes = tier_draft_bytes[s.tier as usize];
                    let ch = chan(&profiles, s);
                    let up = ch.up_ms(bytes);
                    metrics.bytes_up += bytes;
                    air_ms += up;
                    push(&mut heap, &mut seq, t + up + ch.prop_ms, Ev::DraftArrive { sid });
                }
            }
            Ev::WindowClose { rep } => {
                let members: Vec<u32> = {
                    let r = &mut replicas[rep as usize];
                    r.close_armed = false;
                    let n = cfg.max_batch.min(r.backlog.len());
                    (0..n).filter_map(|_| r.backlog.pop_front()).collect()
                };
                debug_assert!(!members.is_empty());
                metrics.queue_depth.add(replicas[rep as usize].backlog.len() as f64);
                let mut dur = A800_70B.t_base_ms;
                for &sid in &members {
                    let s = &sessions[sid as usize];
                    // a tree draft's leaves each ride one ragged row in
                    // the SAME bucket class as the chain (the comb is
                    // bucket-aligned), so the batch still costs one
                    // stacked dispatch but pays per-row verify time
                    let rows = tier_rows[s.tier as usize];
                    dur += per_req_verify_ms * rows as f64;
                    if hetero {
                        metrics.verify_rows += rows;
                        if tier_branch[s.tier as usize] > 1 {
                            metrics.tree_rounds += 1;
                        }
                    }
                    if s.rounds == 0 {
                        // first verify of a session pays its prefill
                        dur += s.prompt_len as f64 * A800_70B.prefill_ms_per_token;
                    }
                    metrics.latency.queue_ms.record(t - s.enqueue_ms);
                    span(
                        trace,
                        t,
                        sid,
                        s.rounds as u32,
                        SpanKind::QueueWait,
                        t - s.enqueue_ms,
                        0,
                        0,
                    );
                }
                metrics.note_batch(members.len());
                // every member drafts the same fixed K, so the planner
                // stacks the whole batch as one [B, K] dispatch class
                metrics.stacked_dispatches += 1;
                if cfg.batch_mode == BatchMode::Continuous {
                    metrics.slot_occupancy.add(members.len() as f64);
                }
                metrics.latency.verify_ms.record(dur);
                if let Some(&sid) = members.iter().find(|&&sid| traced(sid)) {
                    span(
                        trace,
                        t,
                        sid,
                        sessions[sid as usize].rounds as u32,
                        SpanKind::VerifyBatch,
                        dur,
                        members.len() as u32,
                        bucket_k(cfg.fixed_k) as u32,
                    );
                }
                for &sid in &members {
                    let s = &mut sessions[sid as usize];
                    let mut tau = 0u8;
                    for _ in 0..cfg.fixed_k {
                        if s.rng.chance(s.accept as f64) {
                            tau += 1;
                        } else {
                            break;
                        }
                    }
                    // statistical twin of the comb hedge: when the chain
                    // breaks at a bucket-aligned position, one of the
                    // b - 1 alternate leaves catches the divergent token
                    // with probability (b - 1) / SYNTH_ALTS — exactly the
                    // synthetic backend's drift-catch odds. The alternate
                    // is a leaf, so the rescue extends tau by one.
                    if hetero && (tau as usize) < cfg.fixed_k {
                        let b = tier_branch[s.tier as usize];
                        let broke_at = tau as usize + 1;
                        if b > 1
                            && bucket_k(broke_at) == bucket_k(cfg.fixed_k)
                            && s.rng.chance(
                                (b - 1) as f64 / crate::serve::backend::SYNTH_ALTS as f64,
                            )
                        {
                            tau += 1;
                        }
                    }
                    let eos = s.committed as usize + tau as usize + 1 >= s.budget as usize;
                    let ch = chan(&profiles, s);
                    let down = ch.down_ms(verdict_bytes);
                    metrics.bytes_down += verdict_bytes;
                    air_ms += down;
                    push(
                        &mut heap,
                        &mut seq,
                        t + dur + down + ch.prop_ms,
                        Ev::Verdict { sid, tau, eos },
                    );
                }
                replicas[rep as usize].busy = true;
                push(&mut heap, &mut seq, t + dur, Ev::ReplicaFree { rep });
            }
            Ev::ReplicaFree { rep } => {
                let r = &mut replicas[rep as usize];
                r.busy = false;
                if !r.backlog.is_empty() && !r.close_armed {
                    r.close_armed = true;
                    push(&mut heap, &mut seq, t + window_arm_ms, Ev::WindowClose { rep });
                }
            }
            Ev::Verdict { sid, tau, eos } => {
                let s = &mut sessions[sid as usize];
                debug_assert!(!s.done);
                metrics.note_round(cfg.fixed_k, tau as usize);
                metrics.latency.round_ms.record(t - s.send_ms);
                metrics.latency.rtt_ms.record(t - s.send_ms - tier_draft_ms[s.tier as usize]);
                s.rounds += 1;
                s.committed += tau as u16 + 1;
                if hetero {
                    tokens_by_tier[s.tier as usize] += tau as usize + 1;
                }
                if s.first_token_ms.is_nan() {
                    s.first_token_ms = t;
                    ttft_ms.record(t - s.arrived_ms);
                    if hetero {
                        ttft_by_tier[s.tier as usize].record(t - s.arrived_ms);
                    }
                }
                span(
                    trace,
                    t,
                    sid,
                    s.rounds as u32 - 1,
                    SpanKind::Commit,
                    t - s.send_ms,
                    tau as u32,
                    s.committed as u32,
                );
                if eos {
                    s.done = true;
                    live -= 1;
                    metrics.sessions_completed += 1;
                    metrics.session_rounds.add(s.rounds as f64);
                    let drafted = s.rounds as f64 * cfg.fixed_k as f64;
                    metrics
                        .session_acceptance
                        .add((s.committed - s.rounds) as f64 / drafted);
                    ms_per_token.record((t - s.arrived_ms) / s.committed as f64);
                    replicas[s.replica as usize].pinned -= 1;
                } else if s.rng.chance(cfg.abort_p) {
                    s.done = true;
                    live -= 1;
                    metrics.sessions_aborted += 1;
                    replicas[s.replica as usize].pinned -= 1;
                } else {
                    let mut extra = 0.0;
                    if s.rng.chance(cfg.redirect_p) {
                        // ledger handoff to the next replica (the next
                        // ACTIVE one under autoscale): the old replica
                        // redirects, the new one imports
                        metrics.sessions_redirected += 1;
                        metrics.sessions_imported += 1;
                        handoffs += 1;
                        let to = if cfg.autoscale.is_some() {
                            next_active(&replicas, s.replica)
                        } else {
                            (s.replica + 1) % cfg.replicas as u16
                        };
                        replicas[s.replica as usize].pinned -= 1;
                        replicas[to as usize].pinned += 1;
                        s.replica = to;
                        extra = cfg.handoff_ms;
                        span(
                            trace,
                            t,
                            sid,
                            s.rounds as u32,
                            SpanKind::Redirect,
                            cfg.handoff_ms,
                            s.replica as u32,
                            0,
                        );
                    }
                    let bytes = tier_draft_bytes[s.tier as usize];
                    if hetero {
                        energy_j_by_tier[s.tier as usize] += tier_draft_j[s.tier as usize];
                    }
                    let ch = chan(&profiles, s);
                    let up = ch.up_ms(bytes);
                    metrics.bytes_up += bytes;
                    air_ms += up;
                    s.send_ms = t + extra;
                    push(
                        &mut heap,
                        &mut seq,
                        t + extra + tier_draft_ms[s.tier as usize] + up + ch.prop_ms,
                        Ev::DraftArrive { sid },
                    );
                }
            }
            Ev::AutoscaleTick => {
                let ac = cfg.autoscale.as_ref().expect("tick without autoscale config");
                let policy = autoscaler.as_mut().expect("tick without autoscale policy");
                // rebalance directives live for exactly one tick period
                for r in replicas.iter_mut() {
                    r.rebalance_out = None;
                }
                let snaps: Vec<ReplicaSnapshot> = replicas
                    .iter()
                    .enumerate()
                    .filter(|&(_, r)| !r.retired)
                    .map(|(i, r)| ReplicaSnapshot {
                        id: i as u32,
                        active: r.pinned,
                        queue: r.backlog.len(),
                        draining: r.draining,
                        // the sim's telemetry is always fresh; staleness
                        // is exercised by the live controller's tests
                        age_ms: 0.0,
                    })
                    .collect();
                for a in policy.tick(tick_no, &snaps) {
                    // control-plane spans bypass the per-session trace
                    // gate: CONTROL_SESSION marks them in the journal
                    if let Some(tr) = trace {
                        let (arg, _, _) = a.args();
                        tr.clock().advance_to(t);
                        tr.record(
                            CONTROL_SESSION,
                            tick_no as u32,
                            SpanKind::Autoscale,
                            0.0,
                            a.code() as u32,
                            arg as u32,
                        );
                    }
                    match a {
                        AutoscaleAction::ScaleUp { add } => {
                            for _ in 0..add {
                                replicas.push(Replica::default());
                            }
                            replicas_added += add;
                        }
                        AutoscaleAction::ScaleDown { victim } => {
                            replicas[victim as usize].draining = true;
                        }
                        AutoscaleAction::Rebalance { from, to, sessions } => {
                            replicas[from as usize].rebalance_out =
                                Some((to as u16, sessions));
                        }
                    }
                }
                // a drained replica retires once nothing is pinned,
                // queued, or verifying there (its id stays stable)
                for r in replicas.iter_mut() {
                    if r.draining && r.pinned == 0 && r.backlog.is_empty() && !r.busy {
                        r.draining = false;
                        r.retired = true;
                        replicas_retired += 1;
                    }
                }
                tick_no += 1;
                if live > 0 || sessions.len() < cfg.sessions {
                    push(&mut heap, &mut seq, t + ac.tick_ms, Ev::AutoscaleTick);
                }
            }
        }
    }

    debug_assert_eq!(live, 0, "sessions still live after the heap drained");
    let autoscale = autoscaler.map(|p| AutoscaleReport {
        replicas_added,
        replicas_retired,
        redirects: auto_redirects,
        final_replicas: replicas.iter().filter(|r| !r.retired).count(),
        actions: p.log().len(),
        peak_session_redirects,
        log_digest: p.log_digest(),
        log_lines: p
            .log()
            .iter()
            .map(|(t, a)| format!("{t} {}", a.describe()))
            .collect(),
    });
    LoadReport {
        scenario: cfg.scenario.label(),
        sessions: cfg.sessions,
        replicas: cfg.replicas,
        seed: cfg.seed,
        metrics,
        ttft_ms,
        ms_per_token,
        peak_live,
        peak_backlog,
        handoffs,
        events,
        virtual_ms: now,
        air_ms,
        retry_after_min_ms: if retry_after_max == 0 { 0 } else { retry_after_min },
        retry_after_max_ms: retry_after_max,
        autoscale,
        ttft_by_tier,
        energy_j_by_tier,
        tokens_by_tier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::population::Scenario;
    use crate::obs::VirtualClock;

    #[test]
    fn steady_run_is_deterministic_and_conserves() {
        let cfg = Scenario::Steady.config(2000, 42);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.events, b.events);
        assert_eq!(a.virtual_ms.to_bits(), b.virtual_ms.to_bits());
        let v = a.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "{v:?}");
        // autoscale off: the twin's fields are inert
        assert!(a.autoscale.is_none());
        assert_eq!((a.retry_after_min_ms, a.retry_after_max_ms), (0, 0));
        assert_eq!(a.metrics.sessions_opened, 2000);
        // steady never aborts (no admission bound, abort_p == 0), so
        // every session completes and has a first token
        assert_eq!(a.metrics.sessions_completed, 2000);
        assert_eq!(a.metrics.sessions_aborted, 0);
        assert_eq!(a.ttft_ms.count(), 2000);
        assert!(a.peak_live > 0 && a.peak_live <= 2000);
        assert!(a.metrics.tokens_committed > 2000);
        assert!(a.air_ms_per_token() > 0.0);
    }

    #[test]
    fn different_seeds_give_different_digests() {
        let a = run(&Scenario::Steady.config(1000, 3));
        let b = run(&Scenario::Steady.config(1000, 4));
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn flash_overload_floods_live_count_and_queues() {
        let steady = run(&Scenario::Steady.config(4000, 17));
        let flash = run(&Scenario::Flash.config(4000, 17));
        assert!(
            flash.peak_live > 2 * steady.peak_live,
            "flash peak {} vs steady peak {}",
            flash.peak_live,
            steady.peak_live
        );
        let (fq, sq) = (
            flash.metrics.latency.queue_ms.quantile(0.99),
            steady.metrics.latency.queue_ms.quantile(0.99),
        );
        assert!(fq > 2.0 * sq, "flash queue p99 {fq} vs steady {sq}");
        assert!(flash.metrics.invariant_violations(0, 0).is_empty());
    }

    #[test]
    fn churn_exercises_busy_deferrals_and_handoffs() {
        let r = run(&Scenario::Churn.config(3000, 3));
        assert!(r.metrics.drafts_busy > 0, "no Busy deferrals under churn");
        assert!(r.metrics.sessions_redirected > 0, "no handoffs under churn");
        assert_eq!(r.metrics.sessions_redirected, r.metrics.sessions_imported);
        assert_eq!(r.handoffs, r.metrics.sessions_redirected);
        assert!(r.metrics.sessions_aborted > 0, "no aborts under churn");
        let v = r.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "{v:?}");
        // Busy drafts resolve: received == verified + busy
        assert_eq!(
            r.metrics.drafts_received,
            r.metrics.rounds + r.metrics.drafts_busy
        );
    }

    #[test]
    fn continuous_mode_is_deterministic_and_cuts_queue_wait() {
        let mut windowed = Scenario::Steady.config(2000, 42);
        windowed.batch_mode = BatchMode::Windowed;
        let mut rolling = windowed.clone();
        rolling.batch_mode = BatchMode::Continuous;
        let w = run(&windowed);
        let c = run(&rolling);
        assert_eq!(c.digest(), run(&rolling).digest(), "continuous run not deterministic");
        let v = c.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "{v:?}");
        assert_eq!(c.metrics.sessions_completed, 2000);
        // same decode work either way — only the batching schedule moves
        assert_eq!(c.metrics.rounds, w.metrics.rounds);
        assert_eq!(c.metrics.tokens_committed, w.metrics.tokens_committed);
        // rolling admission records one occupancy sample per close and
        // stops making drafts wait out the window
        assert_eq!(c.metrics.slot_occupancy.count(), c.metrics.batches);
        assert_eq!(w.metrics.slot_occupancy.count(), 0);
        let (wq, cq) = (
            w.metrics.latency.queue_ms.quantile(0.99),
            c.metrics.latency.queue_ms.quantile(0.99),
        );
        assert!(cq < wq, "continuous queue p99 {cq} must beat windowed {wq}");
    }

    #[test]
    fn trace_records_spans_for_early_sessions() {
        let cfg = Scenario::Steady.config(500, 7);
        let tr = Trace::new(VirtualClock::shared());
        let r = run_with(&cfg, Some(&tr));
        assert!(tr.len() > 0, "no spans recorded");
        // tracing must not perturb the simulation
        assert_eq!(r.digest(), run(&cfg).digest());
    }

    use crate::autoscale::AutoscaleConfig;

    /// Flash preset with a bounded admission queue and an aggressive
    /// autoscaler — the shape the bench's flash-crowd cell runs.
    fn autoscaled_flash(sessions: usize, seed: u64) -> LoadConfig {
        let mut cfg = Scenario::Flash.config(sessions, seed);
        cfg.admission_queue = 48;
        cfg.autoscale = Some(AutoscaleConfig {
            tick_ms: 500.0,
            min_replicas: cfg.replicas,
            max_replicas: 256,
            scale_up_queue: 4,
            up_ticks: 2,
            cooldown_ticks: 2,
            max_scale_step: 8,
            down_ticks: 20,
            ..AutoscaleConfig::default()
        });
        cfg
    }

    #[test]
    fn autoscale_twin_is_deterministic_and_grows_under_flash() {
        let cfg = autoscaled_flash(6000, 3);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.digest(), b.digest());
        let (ar, br) = (a.autoscale.as_ref().unwrap(), b.autoscale.as_ref().unwrap());
        assert_eq!(ar.log_digest, br.log_digest, "action log must be byte-identical");
        assert!(ar.replicas_added > 0, "flash crowd never triggered a scale-up");
        assert!(ar.final_replicas > cfg.replicas);
        assert!(ar.redirects > 0, "grown fleet never rebalanced");
        assert!(
            ar.peak_session_redirects <= cfg.autoscale.as_ref().unwrap().redirect_budget,
            "per-session redirect budget exceeded: {}",
            ar.peak_session_redirects
        );
        let v = a.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "{v:?}");
        // autoscaler handoffs ride the same accounting as scenario ones
        assert_eq!(a.handoffs, a.metrics.sessions_redirected);
        assert_eq!(a.metrics.sessions_redirected, a.metrics.sessions_imported);
        // the bounded queue deferred drafts and the hints were adaptive:
        // deeper-than-one-window quotes appear under the flash backlog
        assert!(a.metrics.drafts_busy > 0, "flash never hit the admission bound");
        assert!(a.retry_after_max_ms > cfg.window_ms.ceil() as u32);
        assert!(a.retry_after_min_ms >= cfg.window_ms.ceil() as u32);
    }

    #[test]
    fn scale_down_drains_without_stranding_sessions() {
        let mut cfg = Scenario::Steady.config(1500, 17);
        cfg.autoscale = Some(AutoscaleConfig {
            min_replicas: 1,
            down_ticks: 3,
            cooldown_ticks: 1,
            ..AutoscaleConfig::default()
        });
        let r = run(&cfg);
        let a = r.autoscale.as_ref().unwrap();
        assert!(a.replicas_retired > 0, "idle fleet never scaled down");
        assert!(a.final_replicas >= 1);
        // no session is stranded on a retired replica: every admitted
        // session still completes (steady neither aborts nor bounds
        // admission), and the conservation audit balances
        assert_eq!(r.metrics.sessions_completed, r.metrics.sessions_opened);
        let v = r.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn autoscale_twin_traces_control_actions() {
        let cfg = autoscaled_flash(4000, 17);
        let tr = Trace::new(VirtualClock::shared());
        let r = run_with(&cfg, Some(&tr));
        assert!(r.autoscale.as_ref().unwrap().replicas_added > 0);
        assert_eq!(
            tr.count(CONTROL_SESSION, SpanKind::Autoscale),
            r.autoscale.as_ref().unwrap().actions,
            "every control action must journal one span"
        );
        // tracing must not perturb the simulation or the action log
        let quiet = run(&cfg);
        assert_eq!(r.digest(), quiet.digest());
        assert_eq!(
            r.autoscale.as_ref().unwrap().log_digest,
            quiet.autoscale.as_ref().unwrap().log_digest
        );
    }

    #[test]
    fn report_json_and_render_are_complete() {
        let r = run(&Scenario::Steady.config(800, 3));
        let j = r.to_json();
        assert_eq!(j.get("sessions").and_then(|x| x.as_usize()), Some(800));
        assert!(j.get("ttft_ms").and_then(|t| t.get("p99")).is_some());
        assert!(j.get("digest").is_some());
        assert!(j.get("metrics").and_then(|m| m.get("rounds")).is_some());
        let text = r.render();
        assert!(text.contains("load/steady"));
        assert!(text.contains("digest"));
        assert!(text.contains("serving counters"));
        // homogeneous presets stay untouched by the device layer
        assert!(!r.is_hetero());
        assert_eq!(r.metrics.verify_rows, 0);
        assert_eq!(r.metrics.tree_rounds, 0);
        assert!(matches!(j.get("tiers"), Some(Json::Null)));
    }

    #[test]
    fn hetero_run_is_deterministic_and_fills_tier_cells() {
        let cfg = Scenario::Hetero.config(2000, 42);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.digest(), b.digest());
        let v = a.metrics.invariant_violations(0, 0);
        assert!(v.is_empty(), "{v:?}");
        assert!(a.is_hetero());
        // every admitted session drew a tier, and the EVAL mix fills
        // all three cells at this population size
        let profiled: usize = a.metrics.sessions_by_device_tier.iter().sum();
        assert_eq!(profiled, a.metrics.sessions_opened);
        assert!(a.metrics.sessions_by_device_tier.iter().all(|&n| n > 0));
        // mid+strong sessions draft trees: extra rows on the same
        // stacked dispatches, never fewer rows than rounds
        assert!(a.metrics.tree_rounds > 0, "no tree rounds on the hetero mix");
        assert!(a.metrics.verify_rows > a.metrics.rounds);
        assert_eq!(a.metrics.stacked_dispatches, a.metrics.batches);
        // per-tier books balance against the fleet-wide ones
        let tokens: usize = a.tokens_by_tier.iter().sum();
        assert_eq!(tokens, a.metrics.tokens_committed);
        let ttft: usize = (0..3).map(|i| a.ttft_by_tier[i].count()).sum();
        assert_eq!(ttft, a.ttft_ms.count());
        assert!(a.energy_j_by_tier.iter().all(|&j| j > 0.0));
        // weak drafting is pricier per token than strong drafting
        let per_tok = |i: usize| a.energy_j_by_tier[i] / a.tokens_by_tier[i] as f64;
        assert!(per_tok(0) > per_tok(2), "weak tier must pay more J/token");
        let j = a.to_json();
        let tiers = j.get("tiers").and_then(|t| t.as_arr()).expect("tiers cell");
        assert_eq!(tiers.len(), 3);
        assert!(a.render().contains("tier weak"));
    }

    #[test]
    fn hetero_tree_beats_linear_on_accepted_per_dispatch() {
        let tree = Scenario::Hetero.config(2000, 42);
        let mut linear = tree.clone();
        linear.branching = 1;
        let tr = run(&tree);
        let ln = run(&linear);
        // linear hetero runs fan nothing out: one row per round
        assert_eq!(ln.metrics.tree_rounds, 0);
        assert_eq!(ln.metrics.verify_rows, ln.metrics.rounds);
        assert!(ln.metrics.invariant_violations(0, 0).is_empty());
        // the comb hedge strictly raises accepted tokens per stacked
        // dispatch — the same ratio the bench's hetero cell gates
        assert!(
            tr.accepted_per_dispatch() > ln.accepted_per_dispatch(),
            "tree {} <= linear {}",
            tr.accepted_per_dispatch(),
            ln.accepted_per_dispatch()
        );
    }
}
