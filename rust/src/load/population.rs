//! Session populations for the load harness: heterogeneous channel
//! mixes, a compact per-session channel sampler, and scenario presets.
//!
//! At 10^5–10^6 concurrent sessions the per-session state has to stay
//! small. [`StochasticChannel`](crate::channel::StochasticChannel)
//! clones a full [`NetworkProfile`] per instance; the harness instead
//! shares the three profiles fleet-wide and keeps only the AR(1)
//! log-shadow term (f32) and the Gilbert-Elliott fade bit per session —
//! [`sample_channel`] reproduces the exact dynamics of
//! `StochasticChannel::sample` against that compact state, driven by
//! the session's own RNG stream.

use crate::channel::{ChannelState, NetworkKind, NetworkProfile};
use crate::device::DeviceMix;
use crate::util::rng::SplitMix64;

use super::arrival::ArrivalShape;

/// AR(1) correlation of the log-shadowing term — matches
/// `StochasticChannel`.
const RHO: f64 = 0.85;

/// One channel-model step against shared profile + compact state.
///
/// Same math as `StochasticChannel::sample`: AR(1) shadowing on the log
/// rate (stationary sigma == `p.sigma`), a Gilbert-Elliott fade chain,
/// and log-normal propagation jitter. The only difference is where the
/// state lives (caller-owned f32 + bool instead of a per-channel
/// struct) and which RNG stream drives it.
pub fn sample_channel(
    p: &NetworkProfile,
    log_shadow: &mut f32,
    fading: &mut bool,
    rng: &mut SplitMix64,
) -> ChannelState {
    let innov = (1.0 - RHO * RHO).sqrt() * p.sigma;
    let ls = RHO * (*log_shadow as f64) + innov * rng.next_normal();
    *log_shadow = ls as f32;
    if *fading {
        if rng.chance(p.p_exit_fade) {
            *fading = false;
        }
    } else if rng.chance(p.p_enter_fade) {
        *fading = true;
    }
    let shadow = ls.exp();
    let (rate_div, prop_mul) = if *fading {
        (p.fade_rate_div, p.fade_prop_mul)
    } else {
        (1.0, 1.0)
    };
    let prop_jitter = rng.next_lognormal(0.0, p.prop_sigma);
    ChannelState {
        up_bps: (p.up_bps * shadow / rate_div).max(1e3),
        down_bps: (p.down_bps * shadow / rate_div).max(1e3),
        prop_ms: p.prop_ms * prop_jitter * prop_mul,
        fading: *fading,
        loss_rate: if *fading { p.fade_loss_rate } else { p.loss_rate },
    }
}

/// Weighted mix over the three evaluation regimes
/// (5G strong / 4G average / weak WiFi), in `NetworkKind::all()` order.
#[derive(Debug, Clone, Copy)]
pub struct ChannelMix {
    pub weights: [f64; 3],
}

impl ChannelMix {
    /// The paper-ish fleet mix: mostly strong/average links with a
    /// weak-signal tail that dominates the latency quantiles.
    pub const EVAL: ChannelMix = ChannelMix {
        weights: [0.45, 0.40, 0.15],
    };

    pub fn new(w5g: f64, w4g: f64, wwifi: f64) -> ChannelMix {
        let sum = (w5g + w4g + wwifi).max(1e-12);
        ChannelMix {
            weights: [w5g / sum, w4g / sum, wwifi / sum],
        }
    }

    /// `"0.5,0.3,0.2"` (5g,4g,wifi weights) or a single profile alias
    /// (`"4g"`, `"wifi"`, ...) for a homogeneous fleet.
    pub fn parse(s: &str) -> Option<ChannelMix> {
        if let Some(kind) = NetworkKind::parse(s) {
            let idx = NetworkKind::all().iter().position(|k| *k == kind)?;
            let mut weights = [0.0; 3];
            weights[idx] = 1.0;
            return Some(ChannelMix { weights });
        }
        let parts: Vec<f64> = s.split(',').map(|p| p.trim().parse().ok()).collect::<Option<_>>()?;
        if parts.len() != 3 || parts.iter().any(|w| *w < 0.0) || parts.iter().sum::<f64>() <= 0.0 {
            return None;
        }
        Some(ChannelMix::new(parts[0], parts[1], parts[2]))
    }

    /// Draw a class index into `NetworkKind::all()`.
    pub fn pick(&self, rng: &mut SplitMix64) -> u8 {
        let u = rng.next_f64();
        let mut acc = 0.0;
        for (i, w) in self.weights.iter().enumerate() {
            acc += w;
            if u < acc {
                return i as u8;
            }
        }
        2
    }

    pub fn describe(&self) -> String {
        format!(
            "5g:{:.0}% 4g:{:.0}% wifi:{:.0}%",
            self.weights[0] * 100.0,
            self.weights[1] * 100.0,
            self.weights[2] * 100.0
        )
    }
}

/// Named workload shapes the CLI / bench / CI run by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Poisson arrivals at ~60% of fleet capacity — the stable
    /// baseline whose quantiles the trajectory tracks.
    Steady,
    /// A flash crowd: 40x arrival burst that floods the backlogs and
    /// pushes live-session count near the admitted total.
    Flash,
    /// Compressed diurnal wave: crest near capacity, light trough.
    Diurnal,
    /// Hot fleet with a bounded admission queue — exercises Busy
    /// deferrals/backoff, aborts, and cross-replica handoffs.
    Churn,
    /// Heterogeneous device population (wire v8): steady arrivals over
    /// the weak/mid/strong [`DeviceMix::EVAL`] with tier-capped tree
    /// speculation — the load-scale twin of the hetero serving matrix
    /// (`tests/serve_hetero.rs`, docs/HETERO.md).
    Hetero,
}

impl Scenario {
    pub fn parse(s: &str) -> Option<Scenario> {
        match s.to_ascii_lowercase().as_str() {
            "steady" => Some(Scenario::Steady),
            "flash" => Some(Scenario::Flash),
            "diurnal" => Some(Scenario::Diurnal),
            "churn" => Some(Scenario::Churn),
            "hetero" => Some(Scenario::Hetero),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Flash => "flash",
            Scenario::Diurnal => "diurnal",
            Scenario::Churn => "churn",
            Scenario::Hetero => "hetero",
        }
    }

    pub fn all() -> [Scenario; 5] {
        [
            Scenario::Steady,
            Scenario::Flash,
            Scenario::Diurnal,
            Scenario::Churn,
            Scenario::Hetero,
        ]
    }

    /// Preset sized to `sessions`: the replica count scales with the
    /// population and arrival rates are expressed as fractions of the
    /// fleet's estimated service capacity (~1 session/s per replica at
    /// the default batch geometry), so every preset keeps its intended
    /// character — stable, overloaded, wavy — at any scale.
    pub fn config(&self, sessions: usize, seed: u64) -> LoadConfig {
        let replicas = (sessions / 1250).clamp(4, 64);
        let cap = replicas as f64; // ~1 session/s per replica
        let shape = match self {
            Scenario::Steady | Scenario::Hetero => ArrivalShape::steady(0.6 * cap),
            Scenario::Flash => ArrivalShape {
                flash_mult: 40.0,
                flash_start_ms: 30_000.0,
                flash_dur_ms: 120_000.0,
                ..ArrivalShape::steady(0.5 * cap)
            },
            Scenario::Diurnal => ArrivalShape {
                diurnal_amp: 0.8,
                diurnal_period_ms: 600_000.0,
                ..ArrivalShape::steady(0.5 * cap)
            },
            Scenario::Churn => ArrivalShape {
                flash_mult: 6.0,
                flash_start_ms: 20_000.0,
                flash_dur_ms: 30_000.0,
                ..ArrivalShape::steady(0.9 * cap)
            },
        };
        let (admission_queue, abort_p, redirect_p) = match self {
            Scenario::Churn => (48, 0.02, 0.015),
            _ => (0, 0.0, 0.0),
        };
        LoadConfig {
            scenario: *self,
            sessions,
            replicas,
            seed,
            window_ms: 12.0,
            max_batch: 8,
            batch_mode: crate::serve::BatchMode::Windowed,
            fixed_k: 4,
            admission_queue,
            shape,
            mix: ChannelMix::EVAL,
            budget_xm: 8.0,
            budget_alpha: 1.2,
            budget_cap: 192.0,
            prompt_xm: 24.0,
            prompt_alpha: 1.3,
            prompt_cap: 1024.0,
            accept_mean: 0.70,
            accept_sd: 0.10,
            abort_p,
            redirect_p,
            handoff_ms: 40.0,
            autoscale: None,
            device_mix: match self {
                Scenario::Hetero => Some(DeviceMix::EVAL),
                _ => None,
            },
            branching: match self {
                Scenario::Hetero => 4,
                _ => 1,
            },
        }
    }
}

/// Full parameterization of one load run.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub scenario: Scenario,
    /// Total sessions admitted before the arrival stream stops.
    pub sessions: usize,
    pub replicas: usize,
    pub seed: u64,
    /// Admission window span, ms (mirrors `VerifierConfig`).
    pub window_ms: f64,
    pub max_batch: usize,
    /// Windowed (close-the-window) or continuous (rolling slot)
    /// batching — mirrors `VerifierConfig::batch_mode`. Continuous
    /// arms a zero-delay window, so drafts dispatch as soon as the
    /// event loop drains the arrival burst (docs/BATCHING.md).
    pub batch_mode: crate::serve::BatchMode,
    /// Fixed draft-block length (the load model does not adapt K).
    pub fixed_k: usize,
    /// Per-replica backlog bound; 0 = unbounded (no Busy deferrals).
    pub admission_queue: usize,
    pub shape: ArrivalShape,
    pub mix: ChannelMix,
    /// Bounded-Pareto token-budget distribution.
    pub budget_xm: f64,
    pub budget_alpha: f64,
    pub budget_cap: f64,
    /// Bounded-Pareto prompt-length distribution.
    pub prompt_xm: f64,
    pub prompt_alpha: f64,
    pub prompt_cap: f64,
    /// Per-session acceptance probability ~ N(mean, sd), clamped.
    pub accept_mean: f64,
    pub accept_sd: f64,
    /// P(session aborts at a verdict) — client gave up / link died.
    pub abort_p: f64,
    /// P(session is handed to the next replica at a verdict).
    pub redirect_p: f64,
    /// Control-plane cost of one ledger handoff, ms.
    pub handoff_ms: f64,
    /// Closed-loop autoscale twin: `Some` runs the live fleet's own
    /// [`AutoscalePolicy`](crate::autoscale::AutoscalePolicy) on the
    /// virtual clock (replica scale-up/down, rebalancing, adaptive
    /// Busy hints). `None` (every preset) is the fixed-fleet harness,
    /// digest-identical to the pre-autoscale one.
    pub autoscale: Option<crate::autoscale::AutoscaleConfig>,
    /// Heterogeneous device population (wire v8): `Some(mix)` draws a
    /// compute tier per session from the weak/mid/strong weights, prices
    /// drafting at the tier representative's speed/energy, and enables
    /// the statistical tree-speculation twin. `None` (every preset but
    /// `hetero`) is the homogeneous fleet, digest-identical to the
    /// pre-device-layer harness.
    pub device_mix: Option<DeviceMix>,
    /// Requested tree branching factor, capped per tier by
    /// [`ComputeTier::plan_caps`](crate::device::ComputeTier::plan_caps);
    /// 1 = linear chains (every preset but `hetero`). Only takes effect
    /// when `device_mix` is set.
    pub branching: usize,
}

impl LoadConfig {
    /// Draw a session's acceptance probability.
    pub fn draw_accept(&self, rng: &mut SplitMix64) -> f64 {
        (self.accept_mean + self.accept_sd * rng.next_normal()).clamp(0.35, 0.95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Channel;

    #[test]
    fn compact_sampler_matches_stochastic_channel_statistics() {
        // Same dynamics, different RNG stream: the stationary moments
        // and fade occupancy must agree with StochasticChannel.
        for kind in NetworkKind::all() {
            let p = NetworkProfile::new(kind);
            let mut reference = p.channel(9);
            let n = 6000;
            let (mut ref_rate, mut ref_fade) = (0.0, 0usize);
            for i in 0..n {
                let s = reference.sample(i as f64);
                ref_rate += s.up_bps;
                ref_fade += s.fading as usize;
            }
            let mut rng = SplitMix64::new(9).fork(1);
            let (mut ls, mut fading) = (0.0f32, false);
            let (mut rate, mut fade) = (0.0, 0usize);
            for _ in 0..n {
                let s = sample_channel(&p, &mut ls, &mut fading, &mut rng);
                rate += s.up_bps;
                fade += s.fading as usize;
            }
            let (m_ref, m) = (ref_rate / n as f64, rate / n as f64);
            assert!(
                (m / m_ref - 1.0).abs() < 0.35,
                "{kind:?}: mean rate {m} vs reference {m_ref}"
            );
            let (f_ref, f) = (ref_fade as f64 / n as f64, fade as f64 / n as f64);
            assert!(
                (f - f_ref).abs() < 0.08,
                "{kind:?}: fade occupancy {f} vs reference {f_ref}"
            );
        }
    }

    #[test]
    fn compact_sampler_is_deterministic() {
        let p = NetworkProfile::new(NetworkKind::WifiWeak);
        let run = || {
            let mut rng = SplitMix64::new(17);
            let (mut ls, mut fading) = (0.0f32, false);
            (0..200)
                .map(|_| sample_channel(&p, &mut ls, &mut fading, &mut rng).up_bps.to_bits())
                .collect::<Vec<u64>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn mix_parses_and_normalizes() {
        let m = ChannelMix::parse("2,1,1").unwrap();
        assert!((m.weights[0] - 0.5).abs() < 1e-12);
        let homog = ChannelMix::parse("wifi").unwrap();
        assert_eq!(homog.weights, [0.0, 0.0, 1.0]);
        assert!(ChannelMix::parse("1,2").is_none());
        assert!(ChannelMix::parse("zigbee").is_none());
        let mut rng = SplitMix64::new(3);
        let picks: Vec<u8> = (0..100).map(|_| homog.pick(&mut rng)).collect();
        assert!(picks.iter().all(|&c| c == 2));
    }

    #[test]
    fn mix_pick_tracks_weights() {
        let m = ChannelMix::EVAL;
        let mut rng = SplitMix64::new(42);
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[m.pick(&mut rng) as usize] += 1;
        }
        for (i, w) in m.weights.iter().enumerate() {
            let got = counts[i] as f64 / 10_000.0;
            assert!((got - w).abs() < 0.03, "class {i}: {got} vs weight {w}");
        }
    }

    #[test]
    fn scenario_presets_parse_and_scale() {
        for sc in Scenario::all() {
            assert_eq!(Scenario::parse(sc.label()), Some(sc));
            let small = sc.config(10_000, 3);
            let big = sc.config(120_000, 3);
            assert!(big.replicas > small.replicas);
            assert!(big.shape.base_per_s > small.shape.base_per_s);
        }
        assert_eq!(Scenario::parse("rush-hour"), None);
        // churn is the only preset with an admission bound
        assert!(Scenario::Churn.config(10_000, 3).admission_queue > 0);
        assert_eq!(Scenario::Steady.config(10_000, 3).admission_queue, 0);
        // flash burst rate dwarfs fleet capacity
        let f = Scenario::Flash.config(120_000, 3);
        assert!(f.shape.lambda(31_000.0) > 10.0 * f.replicas as f64);
        // hetero is the only preset with a device mix + tree branching
        let h = Scenario::Hetero.config(10_000, 3);
        assert!(h.device_mix.is_some());
        assert_eq!(h.branching, 4);
        for sc in [Scenario::Steady, Scenario::Flash, Scenario::Diurnal, Scenario::Churn] {
            let c = sc.config(10_000, 3);
            assert!(c.device_mix.is_none(), "{sc:?} must stay homogeneous");
            assert_eq!(c.branching, 1, "{sc:?} must stay linear");
        }
    }
}
