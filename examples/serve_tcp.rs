//! Multi-session serving over real localhost TCP + the determinism
//! contract of the loopback transport.
//!
//!     cargo run --release --example serve_tcp
//!
//! Part 1 — REAL SOCKETS: a tokio cloud verification server on
//! 127.0.0.1, five concurrent edge sessions (one OS thread each, like
//! independent devices), cross-connection dynamic batching, and ONE
//! mid-run target-version hot-swap (`gsm8k_lora`, drift 0.35) that live
//! sessions survive — the frozen draft's acceptance visibly drops.
//!
//! Part 2 — DETERMINISM: the same serving stack over in-process
//! `LoopbackTransport`s (same `handle_conn`, same verifier thread) must
//! commit *exactly* the token counts the virtual-clock scheduler
//! simulation commits for the same seed and a fixed stride K=4. No
//! artifacts needed: both sides run the deterministic synthetic
//! draft/target pair.
//!
//! Part 3 — MULTIPLEXING: the same five sessions over ONE TCP
//! connection (stream ids + edge-side mux), committing the same token
//! counts as part 2 — N sessions on one socket batch and decode exactly
//! like N sockets.
//!
//! Part 4 — FAULTS: a seeded `FaultTransport` kills the link mid-round;
//! the edge reconnects, replays the resume handshake, and the committed
//! sequences come out bit-identical to the fault-free run.
//!
//! Part 5 — PIPELINING: the same sessions on a METERED loopback (byte-
//! accurate virtual air time), sequential vs `pipeline_depth = 2`. The
//! pipelined run commits the SAME tokens while exposing strictly fewer
//! round-trip waits — the RTT hiding of `serve::pipeline` — at the cost
//! of extra speculative uplink bytes (drafts cancelled on reject).

use anyhow::Result;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve_with, DraftSource, ServeConfig};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::serve::{
    loopback_fault_dial, run_edge_session, run_session_on, serve_cloud, serve_loopback,
    serve_loopback_mux, EdgeMux, EdgeReport, EdgeSessionConfig, FaultConfig, FaultPlan, FaultSide,
    SyntheticDraft, SyntheticTarget, TcpTransport, VerifierConfig, VerifyBackend,
};

const SEED: u64 = 7;
const SESSIONS: usize = 5;
const MAX_NEW: usize = 24;

fn prompts(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| {
            let mut p = vec![1i32];
            for j in 0..6 {
                p.push(64 + ((i * 7 + j * 13) % 64) as i32);
            }
            p
        })
        .collect()
}

fn main() -> Result<()> {
    let rt = tokio::runtime::Builder::new_multi_thread()
        .worker_threads(2)
        .enable_all()
        .build()?;

    // ---- part 1: concurrent sessions over localhost TCP -------------
    println!("== part 1: multi-session serving over localhost TCP ==");
    let tcp_reports = rt.block_on(async {
        let vcfg = VerifierConfig {
            window_ms: 8.0,
            max_batch: 8,
            seed: SEED,
            ..Default::default()
        };
        let handle = serve_cloud("127.0.0.1:0", vcfg, || {
            Ok(Box::new(SyntheticTarget::new(SEED).with_version("gsm8k_lora", 0.35))
                as Box<dyn VerifyBackend>)
        })
        .await?;
        let addr = handle.addr.to_string();
        println!("cloud verification server on {addr}");

        let mut threads = Vec::new();
        for prompt in prompts(SESSIONS) {
            let addr = addr.clone();
            threads.push(std::thread::spawn(move || -> Result<EdgeReport> {
                let rt = tokio::runtime::Builder::new_current_thread()
                    .enable_all()
                    .build()?;
                rt.block_on(async move {
                    let mut t = TcpTransport::connect(&addr).await?;
                    let mut draft = SyntheticDraft::new(SEED);
                    let ecfg = EdgeSessionConfig {
                        max_new: MAX_NEW,
                        seed: SEED,
                        ..Default::default()
                    };
                    run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
                })
            }));
        }

        // mid-run hot-swap: as soon as sessions are live, evolve the
        // target out from under them (they keep decoding)
        loop {
            tokio::time::sleep(std::time::Duration::from_millis(5)).await;
            if handle.stats().await?.sessions_opened >= 2 {
                break;
            }
        }
        let seq = handle.deploy("gsm8k_lora").await?;
        println!("hot-swapped target to gsm8k_lora (seq {seq}) with sessions in flight");

        let reports: Vec<EdgeReport> = tokio::task::spawn_blocking(move || {
            threads
                .into_iter()
                .map(|t| t.join().expect("edge thread panicked"))
                .collect::<Result<Vec<_>>>()
        })
        .await??;

        let metrics = handle.shutdown().await?;
        println!("{}", metrics.render("TCP serving totals"));
        assert_eq!(metrics.sessions_completed, SESSIONS, "all sessions must complete");
        assert_eq!(metrics.hot_swaps, 1, "the mid-run deploy must have landed");
        Ok::<_, anyhow::Error>(reports)
    })?;
    for r in &tcp_reports {
        println!(
            "  session {:2}: {} tokens in {} rounds, acceptance {:.2}, mean K {:.1}, rtt p50 {:.2} ms",
            r.session,
            r.new_tokens,
            r.rounds,
            r.acceptance(),
            r.k_used.mean(),
            r.rtt_ms.p50(),
        );
    }

    // ---- part 2: loopback reproduces the scheduler simulation -------
    println!("\n== part 2: loopback transport vs virtual-clock simulation ==");
    let det_cfg = ServeConfig {
        users: SESSIONS,
        max_new: MAX_NEW,
        fixed_k: Some(4),
        seed: SEED,
        ..Default::default()
    };
    let mut backend = SyntheticTarget::new(SEED);
    let mut make =
        |_id: u32| -> Result<Box<dyn DraftSource>> { Ok(Box::new(SyntheticDraft::new(SEED))) };
    let sim = serve_with(
        &mut backend,
        &mut make,
        &prompts(SESSIONS),
        &JETSON_ORIN,
        &A800_70B,
        &NetworkProfile::new(NetworkKind::FourG),
        &det_cfg,
    )?;

    let (loop_reports, loop_metrics) = rt.block_on(async {
        let vcfg = VerifierConfig {
            seed: SEED,
            ..Default::default()
        };
        let edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> = prompts(SESSIONS)
            .into_iter()
            .map(|p| (Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>, p))
            .collect();
        let ecfg = EdgeSessionConfig {
            max_new: MAX_NEW,
            fixed_k: Some(4),
            seed: SEED,
            ..Default::default()
        };
        serve_loopback(
            vcfg,
            || Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>),
            edges,
            ecfg,
        )
        .await
    })?;

    println!("{}", loop_metrics.render("loopback serving totals"));
    for (i, (lr, so)) in loop_reports.iter().zip(&sim.per_session).enumerate() {
        println!(
            "  prompt {i}: loopback {} tokens / {} accepted / {} rounds  |  sim {} / {} / {}",
            lr.new_tokens, lr.accepted, lr.rounds, so.new_tokens, so.accepted, so.rounds
        );
        assert_eq!(lr.new_tokens, so.new_tokens, "token count diverged on prompt {i}");
        assert_eq!(lr.accepted, so.accepted, "accepted count diverged on prompt {i}");
        assert_eq!(lr.drafted, so.drafted, "drafted count diverged on prompt {i}");
        assert_eq!(lr.rounds, so.rounds, "round count diverged on prompt {i}");
    }
    println!(
        "\nloopback == simulation for seed {SEED}: {} sessions, {} tokens, acceptance {:.3}",
        SESSIONS,
        sim.tokens,
        loop_metrics.acceptance_rate()
    );

    // ---- part 3: five sessions multiplexed over ONE TCP connection --
    println!("\n== part 3: {SESSIONS} sessions multiplexed over one TCP connection ==");
    let (mux_reports, mux_metrics) = rt.block_on(async {
        let vcfg = VerifierConfig {
            seed: SEED,
            ..Default::default()
        };
        let handle = serve_cloud("127.0.0.1:0", vcfg, || {
            Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>)
        })
        .await?;
        let addr = handle.addr.to_string();
        let ecfg = EdgeSessionConfig {
            max_new: MAX_NEW,
            fixed_k: Some(4),
            seed: SEED,
            ..Default::default()
        };
        let initial = TcpTransport::connect(&addr).await?;
        let mut mux = EdgeMux::connect(Box::new(initial), None, &ecfg).await?;
        let mut tasks = Vec::new();
        for prompt in prompts(SESSIONS) {
            let mut stream = mux.open_stream();
            let ecfg = ecfg.clone();
            tasks.push(tokio::spawn(async move {
                let sid = stream.stream_id();
                let mut draft = SyntheticDraft::new(SEED);
                run_session_on(&mut stream, sid, &mut draft, &prompt, &ecfg).await
            }));
        }
        let mut reports: Vec<EdgeReport> = Vec::new();
        for t in tasks {
            reports.push(t.await.expect("mux session task panicked")?);
        }
        drop(mux);
        let metrics = handle.shutdown().await?;
        Ok::<_, anyhow::Error>((reports, metrics))
    })?;
    println!("{}", mux_metrics.render("muxed TCP serving totals"));
    for (i, (mr, so)) in mux_reports.iter().zip(&sim.per_session).enumerate() {
        assert_eq!(mr.new_tokens, so.new_tokens, "mux tokens diverged (prompt {i})");
        assert_eq!(mr.rounds, so.rounds, "mux rounds diverged (prompt {i})");
    }
    println!(
        "one connection, {} streams: token counts identical to part 2 and the simulator",
        SESSIONS
    );

    // ---- part 4: seeded link faults + reconnect-and-resume ----------
    println!("\n== part 4: forced disconnects + resume (loopback, seeded) ==");
    let fault_free = rt.block_on(async {
        let vcfg = VerifierConfig {
            seed: SEED,
            ..Default::default()
        };
        let edges: Vec<(Box<dyn DraftSource + Send>, Vec<i32>)> = prompts(SESSIONS)
            .into_iter()
            .map(|p| (Box::new(SyntheticDraft::new(SEED)) as Box<dyn DraftSource + Send>, p))
            .collect();
        let ecfg = EdgeSessionConfig {
            max_new: MAX_NEW,
            fixed_k: Some(4),
            seed: SEED,
            ..Default::default()
        };
        serve_loopback_mux(
            vcfg,
            || Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>),
            edges,
            ecfg,
        )
        .await
    })?;

    let faulty_reports = rt.block_on(async {
        let verifier = flexspec::serve::VerifierHandle::spawn(
            VerifierConfig {
                seed: SEED,
                ..Default::default()
            },
            || Ok(Box::new(SyntheticTarget::new(SEED)) as Box<dyn VerifyBackend>),
        )?;
        let mut tasks = Vec::new();
        for (i, prompt) in prompts(SESSIONS).into_iter().enumerate() {
            let plan = FaultPlan::shared(
                FaultConfig {
                    seed: SEED + i as u64,
                    max_disconnects: 1,
                    disconnect_gap: (5, 10),
                    disconnect_on: FaultSide::Any,
                    ..Default::default()
                },
                NetworkProfile::new(NetworkKind::FourG).channel(SEED + i as u64),
            );
            let dial = loopback_fault_dial(verifier.clone(), plan);
            let ecfg = EdgeSessionConfig {
                max_new: MAX_NEW,
                fixed_k: Some(4),
                seed: SEED,
                max_reattach: 16,
                ..Default::default()
            };
            tasks.push(tokio::spawn(async move {
                let mut t = flexspec::serve::ResumableTransport::connect(dial, &ecfg).await?;
                let mut draft = SyntheticDraft::new(SEED);
                run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
            }));
        }
        let mut reports: Vec<EdgeReport> = Vec::new();
        for t in tasks {
            reports.push(t.await.expect("faulty session task panicked")?);
        }
        let metrics = verifier.shutdown().await?;
        println!("{}", metrics.render("fault-injected serving totals"));
        Ok::<_, anyhow::Error>(reports)
    })?;
    let total_resumes: usize = faulty_reports.iter().map(|r| r.resumes).sum();
    for (i, (fr, clean)) in faulty_reports.iter().zip(&fault_free.0).enumerate() {
        assert_eq!(
            fr.committed, clean.committed,
            "fault-injected committed sequence diverged (prompt {i})"
        );
    }
    println!(
        "{} forced disconnects survived; committed sequences bit-identical to the fault-free run",
        total_resumes
    );

    // ---- part 5: pipelined vs sequential on a metered loopback ------
    println!("\n== part 5: pipelined drafting (depth 2) vs sequential, metered loopback ==");
    let pipeline_run = |depth: usize| -> Result<(Vec<EdgeReport>, f64, usize)> {
        rt.block_on(async {
            let verifier = flexspec::serve::VerifierHandle::spawn(
                VerifierConfig {
                    seed: SEED,
                    ..Default::default()
                },
                || {
                    // a drifted target so some speculation genuinely
                    // breaks (cancel-on-reject in action)
                    let mut t = SyntheticTarget::new(SEED).with_version("gsm8k_lora", 0.3);
                    t.deploy("gsm8k_lora")?;
                    Ok(Box::new(t) as Box<dyn VerifyBackend>)
                },
            )?;
            let mut tasks = Vec::new();
            let mut ledgers = Vec::new();
            for (i, prompt) in prompts(SESSIONS).into_iter().enumerate() {
                let chan = NetworkProfile::new(NetworkKind::FourG).channel(SEED + i as u64);
                let (edge_t, cloud_t, ledger) =
                    flexspec::serve::loopback_pair_with_channel(chan);
                ledgers.push(ledger);
                let v = verifier.clone();
                tokio::spawn(async move {
                    let _ = flexspec::serve::handle_conn(cloud_t, v).await;
                });
                let ecfg = EdgeSessionConfig {
                    max_new: MAX_NEW,
                    fixed_k: Some(4),
                    seed: SEED,
                    pipeline_depth: depth,
                    ..Default::default()
                };
                tasks.push(tokio::spawn(async move {
                    let mut t = edge_t;
                    let mut draft = SyntheticDraft::new(SEED);
                    run_edge_session(&mut t, &mut draft, &prompt, &ecfg).await
                }));
            }
            let mut reports: Vec<EdgeReport> = Vec::new();
            for t in tasks {
                reports.push(t.await.expect("pipelined session task panicked")?);
            }
            let metrics = verifier.shutdown().await?;
            println!("{}", metrics.render(&format!("depth-{depth} serving totals")));
            let air_ms: f64 = ledgers.iter().map(|l| l.lock().unwrap().air_ms).sum();
            let frames: usize = ledgers.iter().map(|l| l.lock().unwrap().frames).sum();
            Ok::<_, anyhow::Error>((reports, air_ms, frames))
        })
    };
    let (seq_reports, seq_air, seq_frames) = pipeline_run(1)?;
    let (pipe_reports, pipe_air, pipe_frames) = pipeline_run(2)?;
    for (i, (s, p)) in seq_reports.iter().zip(&pipe_reports).enumerate() {
        assert_eq!(
            s.committed, p.committed,
            "pipelined committed sequence diverged (prompt {i})"
        );
    }
    let seq_exposed: usize = seq_reports.iter().map(|r| r.exposed_waits).sum();
    let pipe_exposed: usize = pipe_reports.iter().map(|r| r.exposed_waits).sum();
    let piped: usize = pipe_reports.iter().map(|r| r.rounds_pipelined).sum();
    let cancelled: usize = pipe_reports.iter().map(|r| r.drafts_cancelled).sum();
    assert!(
        pipe_exposed < seq_exposed,
        "pipelining must hide round trips ({pipe_exposed} !< {seq_exposed})"
    );
    println!(
        "same committed tokens; exposed RTT waits {seq_exposed} -> {pipe_exposed} \
         ({piped} rounds pipelined, {cancelled} drafts cancelled)"
    );
    println!(
        "virtual air: {seq_air:.1} ms / {seq_frames} frames sequential -> \
         {pipe_air:.1} ms / {pipe_frames} frames pipelined \
         (speculation trades uplink bytes for hidden round trips)"
    );
    Ok(())
}
