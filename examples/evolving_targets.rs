//! The paper's headline scenario: the CLOUD target keeps evolving (LoRA
//! hot-swaps per domain, plus a full-parameter drift), while the EDGE
//! draft stays frozen. Shows acceptance + speedup per deployed version
//! for the anchor-aligned FlexSpec draft vs the generic Std-SD draft,
//! and the sync traffic a tightly-coupled method would have shipped.

use flexspec::baselines::Method;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::sync;
use flexspec::coordinator::{CloudEngine, Pipeline};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::experiments::REGIME_A;
use flexspec::runtime::Registry;
use flexspec::util::table::Table;
use flexspec::workload::{WorkloadGen, EOS};

fn main() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    // the cloud's release train: five successive deployments
    let releases: &[(&str, &str)] = &[
        ("target_llama2t_base", "general"),
        ("lora_llama2t_gsm8k", "gsm8k"),
        ("lora_llama2t_nq", "nq"),
        ("lora_llama2t_cnndm", "cnndm"),
        ("target_llama2t_code_full", "humaneval"),
    ];

    let mut t = Table::new(
        "frozen edge drafts vs an evolving cloud (4G, greedy)",
        &["Deployed version", "Workload", "FlexSpec acc", "FlexSpec spd",
          "Std-SD acc", "Std-SD spd", "sync shipped"],
    );

    let mut cloud = CloudEngine::new(&reg, releases[0].0, EOS)?;
    for (i, (version, domain)) in releases.iter().enumerate() {
        if i > 0 {
            cloud.deploy(&reg, version)?; // hot-swap; the edge is not told
        }
        let mut row = vec![version.to_string(), domain.to_string()];
        let mut co_ms = 0.0;
        for method in [Method::CloudOnly, Method::FlexSpec, Method::StdSd] {
            let mut gen = WorkloadGen::new(domain, 11)?;
            let (mut accept, mut ms) = (0.0, 0.0);
            let n = 3;
            for r in 0..n {
                let req = gen.next_request();
                let mut chan = NetworkProfile::new(NetworkKind::FourG).channel(100 + r as u64);
                let mut pipe = Pipeline::new(
                    method.draft_source(&reg, "llama2t", domain)?,
                    &mut cloud,
                    &mut chan,
                    method.stride_policy(NetworkKind::FourG),
                    &JETSON_ORIN,
                    &A800_70B,
                    REGIME_A.mode,
                    REGIME_A.temperature,
                    REGIME_A.top_p,
                    method.label(),
                );
                let res = pipe.run_request(&req.prompt, req.max_new, r as u64)?;
                accept += res.acceptance_rate() / n as f64;
                ms += res.ms_per_token() / n as f64;
            }
            match method {
                Method::CloudOnly => co_ms = ms,
                _ => {
                    row.push(format!("{accept:.2}"));
                    row.push(format!("{:.2}x", co_ms / ms));
                }
            }
        }
        // what a synced method would have downloaded for this release
        let traffic = if i == 0 {
            0
        } else {
            sync::method_update_traffic("eagle2").bytes_per_update_per_user
        };
        row.push(if traffic == 0 {
            "0 B".into()
        } else {
            format!("{:.1} GB", traffic as f64 / 1e9)
        });
        t.row(row);
    }
    println!("{}", t.render());
    println!(
        "FlexSpec shipped 0 bytes across {} cloud releases; an EAGLE-2-style\n\
         deployment would have shipped {:.1} GB per user (Table I economics).",
        releases.len() - 1,
        (releases.len() - 1) as f64
            * sync::method_update_traffic("eagle2").bytes_per_update_per_user as f64
            / 1e9,
    );
    Ok(())
}
