//! Channel-aware adaptation in action: one long request over a weak,
//! *fading* WiFi link. Prints the channel state the edge measured each
//! round, the latency model it built, the K* it chose, and what happened
//! — the live trace of paper Fig. 2 / Fig. 5.

use flexspec::baselines::Method;
use flexspec::channel::{Channel, NetworkKind, NetworkProfile};
use flexspec::coordinator::policy::LatencyModel;
use flexspec::coordinator::{CloudEngine, Pipeline};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::experiments::REGIME_A;
use flexspec::protocol::WireFormat;
use flexspec::runtime::Registry;
use flexspec::workload::{WorkloadGen, EOS};

fn main() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    let mut gen = WorkloadGen::new("mtbench", 5)?;
    let req = gen.next_request();

    // preview the channel weather this seed produces
    let mut preview = NetworkProfile::new(NetworkKind::WifiWeak).channel(21);
    println!("weak-WiFi weather for the next ~20 rounds:");
    for i in 0..20 {
        let s = preview.sample(i as f64 * 800.0);
        let lat = LatencyModel::build(&s, &JETSON_ORIN, &A800_70B, WireFormat::Compact);
        println!(
            "  t~{:5.0}ms  rate {:7.2} Mbps  prop {:5.1} ms  {}  T_fixed {:6.0} T_marg {:5.1}",
            i as f64 * 800.0,
            s.up_bps / 1e6,
            s.prop_ms,
            if s.fading { "FADE" } else { "    " },
            lat.t_fixed_ms,
            lat.t_marginal_ms,
        );
    }

    for method in [Method::FlexSpec, Method::Dssd] {
        let mut cloud = CloudEngine::new(&reg, "lora_llama2t_mtbench", EOS)?;
        let mut chan = NetworkProfile::new(NetworkKind::WifiWeak).channel(21);
        let mut pipe = Pipeline::new(
            method.draft_source(&reg, "llama2t", "mtbench")?,
            &mut cloud,
            &mut chan,
            method.stride_policy(NetworkKind::WifiWeak),
            &JETSON_ORIN,
            &A800_70B,
            REGIME_A.mode,
            REGIME_A.temperature,
            REGIME_A.top_p,
            method.label(),
        );
        let r = pipe.run_request(&req.prompt, req.max_new, 13)?;
        println!(
            "\n[{}] {:.1} ms/token over fading WiFi ({} rounds, accept {:.2})",
            method.label(),
            r.ms_per_token(),
            r.rounds,
            r.acceptance_rate()
        );
        println!("  round  K  tau  t_step(ms)  uplink(ms)  fade");
        for (i, l) in r.rounds_log.iter().enumerate().take(18) {
            println!(
                "  {:5}  {}  {:3}  {:9.0}  {:9.0}  {}",
                i,
                l.k,
                l.tau,
                l.t_step_ms,
                l.t_up_ms,
                if l.fading { "yes" } else { "" }
            );
        }
    }
    println!(
        "\nFlexSpec shrinks K during fades (big uplink cost) and stretches it\n\
         when the channel recovers; DSSD's class heuristic cannot see fades."
    );
    Ok(())
}
