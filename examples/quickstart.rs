//! Quickstart: one FlexSpec request end-to-end vs the Cloud-Only anchor.
//!
//!     make artifacts                 # once: trains + AOT-lowers the zoo
//!     cargo run --release --example quickstart
//!
//! Loads the AOT model zoo through PJRT, serves a GSM8K-style request
//! against the math-evolved cloud target with the FROZEN anchor-aligned
//! edge draft, and prints the per-round adaptive strides and the speedup.

use flexspec::baselines::Method;
use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{CloudEngine, Pipeline};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::experiments::REGIME_A;
use flexspec::runtime::Registry;
use flexspec::workload::{WorkloadGen, EOS};

fn main() -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    println!("loaded model zoo from {:?}", reg.manifest.root);
    println!("target versions available: {:?}", reg.names_of_kind("lora"));

    let n_requests = 4;
    let mut totals: Vec<(f64, usize)> = Vec::new(); // (decode_ms, tokens) per method
    for method in [Method::CloudOnly, Method::FlexSpec].iter() {
        // the cloud serves the MATH-EVOLVED target; the edge draft is the
        // static FlexSpec bundle that has never seen this version.
        let mut cloud = CloudEngine::new(&reg, "lora_llama2t_gsm8k", EOS)?;
        let mut gen = WorkloadGen::new("gsm8k", 42)?;
        let mut total = (0.0, 0usize);
        for i in 0..n_requests {
            let req = gen.next_request();
            let mut chan = NetworkProfile::new(NetworkKind::FourG).channel(7 + i);
            let mut pipe = Pipeline::new(
                method.draft_source(&reg, "llama2t", "gsm8k")?,
                &mut cloud,
                &mut chan,
                method.stride_policy(NetworkKind::FourG),
                &JETSON_ORIN,
                &A800_70B,
                REGIME_A.mode,
                REGIME_A.temperature,
                REGIME_A.top_p,
                method.label(),
            );
            let r = pipe.run_request(&req.prompt, req.max_new, i)?;
            println!(
                "[{}] req {i}: {} tokens, {:.1} ms/token, {} rounds, acceptance {:.2}",
                method.label(),
                r.new_tokens,
                r.ms_per_token(),
                r.rounds,
                r.acceptance_rate()
            );
            if *method == Method::FlexSpec && i == 1 {
                print!("    per-round K(tau): ");
                for l in r.rounds_log.iter().take(14) {
                    print!("{}({}) ", l.k, l.tau);
                }
                println!("...");
            }
            total.0 += r.decode_ms;
            total.1 += r.new_tokens;
        }
        totals.push(total);
        println!();
    }
    let co = totals[0].0 / totals[0].1 as f64;
    let fs = totals[1].0 / totals[1].1 as f64;
    println!("mean ms/token: Cloud-Only {co:.1} vs FlexSpec {fs:.1}");
    println!("FlexSpec speedup vs Cloud-Only on 4G (math-evolved target, frozen draft): {:.2}x", co / fs);
    Ok(())
}
