//! End-to-end serving driver (DESIGN.md end-to-end validation): load the
//! real (tiny) model zoo, serve a batched multi-user workload through the
//! full stack — workload generator → edge drafting (PJRT) → wire protocol
//! → dynamic verification batching on the cloud engine (PJRT + fused
//! Pallas verify kernel) → KV rollback — and report latency/throughput.
//!
//!     cargo run --release --example serve_e2e [users] [network]
//!
//! Results of the recorded run live in EXPERIMENTS.md §End-to-end.

use flexspec::channel::{NetworkKind, NetworkProfile};
use flexspec::coordinator::{serve, CloudEngine, ServeConfig};
use flexspec::devices::{A800_70B, JETSON_ORIN};
use flexspec::runtime::Registry;
use flexspec::workload::{WorkloadGen, EOS};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let users: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let network = args
        .get(2)
        .and_then(|s| NetworkKind::parse(s))
        .unwrap_or(NetworkKind::FourG);

    let reg = Registry::open_default()?;
    let draft = reg.model("draft_flex_llama2t")?;
    println!(
        "edge draft: {} ({} params, {:.1} MB) — frozen across every cloud version",
        draft.weights.info.name,
        draft.weights.n_params,
        draft.weights.byte_size as f64 / 1e6
    );

    // mixed workload: chat + QA + math sessions
    let mut prompts = Vec::new();
    for (i, ds) in ["mtbench", "nq", "gsm8k"].iter().cycle().take(users).enumerate() {
        let mut gen = WorkloadGen::new(ds, 1000 + i as u64)?;
        prompts.push(gen.next_request().prompt);
    }

    let mut cloud = CloudEngine::new(&reg, "lora_llama2t_mtbench", EOS)?;
    let cfg = ServeConfig {
        users,
        max_new: 32,
        window_ms: 12.0,
        max_batch: 8,
        arrival_mean_ms: 250.0,
        seed: 3,
        ..Default::default()
    };
    let net = NetworkProfile::new(network);
    println!(
        "serving {users} sessions over {} (window {} ms, max batch {})...",
        network.label(),
        cfg.window_ms,
        cfg.max_batch
    );
    let t0 = std::time::Instant::now();
    let rep = serve(&mut cloud, draft, &prompts, &JETSON_ORIN, &A800_70B, &net, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== serve report ===");
    println!("completed sessions   {}", rep.completed);
    println!("tokens generated     {}", rep.tokens);
    println!("virtual wall time    {:.1} s", rep.wall_ms / 1e3);
    println!("virtual throughput   {:.1} tok/s", rep.throughput_tok_s());
    println!("verification rounds  {} in {} batches (mean batch {:.2})", rep.rounds, rep.batches, rep.mean_batch);
    println!("T_base amortized     {:.1} s of cloud time saved", rep.t_base_saved_ms / 1e3);
    println!("request latency      p50 {:.0} ms   p95 {:.0} ms", rep.request_latency.p50(), rep.request_latency.p95());
    println!("per-token latency    p50 {:.0} ms   p95 {:.0} ms", rep.per_token_latency.p50(), rep.per_token_latency.p95());
    println!("draft acceptance     {:.2}", rep.acceptance.mean());
    println!("host wall clock      {wall:.1} s (real PJRT execution of every round)");
    Ok(())
}
